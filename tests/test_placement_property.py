"""Hypothesis property sweep for the pipeline placement solver.

Kept separate from test_pipeline_parallel.py so the differential suite
still collects when hypothesis is not installed (the dep lives in
requirements-dev.txt).  The solver is pure — no jax, no devices — so
these sweeps are cheap and wide:

* every ``(model, stage)`` pair gets exactly one device in range;
* the achieved ``max_load`` never exceeds the SOUND greedy guarantee
  ``total/M + c_max`` (the classic 4/3 LPT ratio bounds OPT, not the
  achieved load — costs [3, 3, 3] on 2 devices packs to 6 > 4/3-of-OPT-
  lower-bound, so that is deliberately NOT asserted here);
* loads conserve the total cost and ``opt_lower <= max_load``;
* fixed seed => identical placement (re-solves after a device kill must
  be reproducible);
* degenerate inputs (one device, more stages than devices, zero-cost
  stages) solve rather than crash.
"""
import pytest

pytest.importorskip('hypothesis')

from hypothesis import given, settings, strategies as st     # noqa: E402

from repro.serving.placement import (DEFAULT_MODEL,          # noqa: E402
                                     lpt_ratio, solve_placement)

costs_st = st.lists(st.floats(0.0, 1e4, allow_nan=False,
                              allow_infinity=False),
                    min_size=1, max_size=24)


@settings(max_examples=200, deadline=None)
@given(costs=costs_st, n=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_every_stage_placed_within_guarantee(costs, n, seed):
    p = solve_placement(costs, n, seed=seed)
    assert p.n_devices == n
    placed = dict(p.assignment)
    assert set(placed) == {(DEFAULT_MODEL, k) for k in range(len(costs))}
    assert all(0 <= d < n for d in placed.values())
    total = sum(costs)
    tol = 1e-9 * max(1.0, total)
    assert abs(sum(p.loads) - total) <= tol          # cost conserved
    assert p.max_load <= p.guarantee + tol           # sound greedy bound
    assert p.opt_lower <= p.max_load + tol           # lower-bounds OPT
    assert p.bound >= p.opt_lower - tol              # ratio >= 1
    assert abs(p.guarantee - (total / n + max(costs))) <= tol


@settings(max_examples=100, deadline=None)
@given(costs=costs_st, n=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_deterministic_under_fixed_seed(costs, n, seed):
    a = solve_placement(costs, n, seed=seed)
    b = solve_placement(costs, n, seed=seed)
    assert a.assignment == b.assignment and a.loads == b.loads


@settings(max_examples=100, deadline=None)
@given(models=st.dictionaries(
           st.sampled_from(['cnn-a', 'cnn-b', 'cnn-c']),
           st.lists(st.floats(0.0, 100.0), min_size=1, max_size=6),
           min_size=1, max_size=3),
       n=st.integers(1, 8))
def test_multi_model_packing(models, n):
    p = solve_placement(models, n)
    keys = {(m, k) for m, cs in models.items() for k in range(len(cs))}
    assert set(dict(p.assignment)) == keys
    for m, cs in models.items():
        for k in range(len(cs)):
            assert 0 <= p.device_of(k, model=m) < n
    total = sum(sum(cs) for cs in models.values())
    assert p.max_load <= total / n + max(
        c for cs in models.values() for c in cs) + 1e-9 * max(1.0, total)


def test_degenerate_cases_solve():
    one = solve_placement([5.0, 1.0, 2.0], 1)
    assert one.loads == (8.0,) and one.balance == 1.0
    crowded = solve_placement(list(range(1, 20)), 3)
    assert len(crowded.assignment) == 19
    zeros = solve_placement([0.0, 0.0, 0.0], 4)
    assert zeros.max_load == 0.0 and zeros.balance == 1.0
    single = solve_placement([7.0], 8)
    assert single.max_load == 7.0 and single.opt_lower == 7.0


def test_invalid_inputs_raise():
    with pytest.raises(ValueError):
        solve_placement([1.0], 0)
    with pytest.raises(ValueError):
        solve_placement([], 2)
    with pytest.raises(ValueError):
        solve_placement([1.0, -2.0], 2)
    with pytest.raises(ValueError):
        solve_placement([float('nan')], 2)
    with pytest.raises(ValueError):
        solve_placement({'a': []}, 2)


def test_lpt_ratio_monotone():
    assert lpt_ratio(1) == 1.0
    rs = [lpt_ratio(n) for n in range(1, 16)]
    assert rs == sorted(rs) and all(r < 4 / 3 for r in rs)
