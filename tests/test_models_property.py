"""Property-based tests on model-substrate invariants (hypothesis)."""
import pytest

pytest.importorskip('hypothesis')

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from hypothesis import given, settings, strategies as st     # noqa: E402

from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.layers import causal_conv1d, conv1d_step, init_conv1d


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(3, 48),
       chunk=st.sampled_from([4, 8, 16, 64]))
def test_chunked_attention_matches_dense(seed, S, chunk):
    """Online-softmax chunked attention == dense softmax attention for any
    chunk size (the flash invariant)."""
    B, H, K, D = 2, 4, 2, 16
    k = jax.random.key(seed)
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attn.chunked_attention(q, kk, vv, pos, pos, chunk=chunk)
    # dense oracle
    g = H // K
    qg = q.reshape(B, S, K, g, D) * D ** -0.5
    logits = jnp.einsum('bskgd,btkd->bskgt', qg, kk)
    mask = pos[None, :] <= pos[:, None]
    logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    expect = jnp.einsum('bskgt,btkd->bskgd', p, vv).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), window=st.integers(1, 16))
def test_local_attention_respects_window(seed, window):
    """A token must not attend outside its sliding window: outputs equal
    attention over explicitly truncated keys."""
    B, H, K, D, S = 1, 2, 2, 8, 24
    k = jax.random.key(seed)
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attn.chunked_attention(q, kk, vv, pos, pos, window=window, chunk=8)
    i = S - 1
    lo = max(0, i - window + 1)
    out_last = attn.chunked_attention(q[:, i:i + 1], kk[:, lo:i + 1],
                                      vv[:, lo:i + 1], pos[i:i + 1],
                                      pos[lo:i + 1], chunk=8)
    np.testing.assert_allclose(np.asarray(out[:, i]),
                               np.asarray(out_last[:, 0]),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from([2, 4, 8, 32]))
def test_ssd_chunk_invariance(seed, chunk):
    """Mamba-2 SSD output must not depend on the chunk size (the state-space
    duality identity)."""
    b, l, h, p, n = 1, 32, 4, 8, 16
    k = jax.random.key(seed)
    x = jax.random.normal(k, (b, l, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (b, l, h))) * 0.1
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, l, n))
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, l, n))
    y1, s1 = rec.ssd_chunked(x, a, B, C, chunk)
    y2, s2 = rec.ssd_chunked(x, a, B, C, l)         # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ssd_matches_sequential_recurrence(seed):
    """SSD chunked == naive per-step SSM recurrence."""
    b, l, h, p, n = 1, 12, 2, 4, 8
    k = jax.random.key(seed)
    x = jax.random.normal(k, (b, l, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (b, l, h))) * 0.2
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, l, n))
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, l, n))
    y, state = rec.ssd_chunked(x, a, B, C, 4)
    hst = np.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        hst = hst * np.exp(np.asarray(a[:, t]))[:, :, None, None] \
            + np.einsum('bhp,bn->bhpn', np.asarray(x[:, t]),
                        np.asarray(B[:, t]))
        ys.append(np.einsum('bn,bhpn->bhp', np.asarray(C[:, t]), hst))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), hst, rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(4, 24))
def test_conv1d_step_matches_full(seed, S):
    """Streaming conv (decode) == full causal conv at every position."""
    C, kk = 6, 4
    key = jax.random.key(seed)
    p = init_conv1d(key, C, kk)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, C))
    full = causal_conv1d(p, x)
    state = jnp.zeros((2, kk - 1, C))
    for t in range(S):
        y, state = conv1d_step(p, x[:, t], state)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cur=st.integers(0, 30))
def test_ring_buffer_cache_write(seed, cur):
    """Decode cache ring write lands at slot cur % window and keeps the
    newest positions."""
    B, K, D, W = 1, 2, 8, 8
    key = jax.random.key(seed)
    cache = attn.init_attn_cache(
        type('C', (), {'window': W, 'num_kv_heads': K, 'head_dim': D,
                       'kv_cache_bits': 0})(),
        B, 'local', 64, jnp.float32)
    q = jax.random.normal(key, (B, 4, D))
    nk = jax.random.normal(jax.random.fold_in(key, 1), (B, K, D))
    nv = jax.random.normal(jax.random.fold_in(key, 2), (B, K, D))
    out, new_cache = attn.decode_attn_reference(
        q, nk, nv, cache, jnp.asarray(cur), window=W)
    ck, pos = new_cache['k'], new_cache['meta']['pos']
    slot = cur % W
    np.testing.assert_allclose(np.asarray(ck[:, slot]), np.asarray(nk))
    assert int(pos[slot]) == cur
    # only the new token is valid -> attention output == v of the new token
    g = 4 // K
    np.testing.assert_allclose(np.asarray(out.reshape(B, K, g, D)),
                               np.broadcast_to(np.asarray(nv)[:, :, None, :],
                                               (B, K, g, D)), rtol=1e-5)
