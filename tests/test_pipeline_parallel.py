"""Pipeline-parallel serving: the forced-8-device differential suite.

The heart is one subprocess under ``XLA_FLAGS
=--xla_force_host_platform_device_count=8`` (device count is locked at
backend init — see conftest.force_host_device_count) that serves the
same trace through the placed pipeline for all 3 CNN kinds (resnet,
vgg, mobilenet) x {compacting, static-cohort} x a chaos device kill,
asserting every request's logits and exit stage BIT-EXACT against the
monolithic single-device ``fn_exits`` serving it alone at the same slot
geometry — placement moves where stages run, never what they compute.
Each run also validates its trace invariants and the
placement-consistency analysis rule on the live placed model.

In-process tests cover the conftest device-count guard (raises once the
backend is up; a fresh subprocess proves the pre-init path), the
registry's multi-model placement planning, and ``launch/mesh.py`` as
consumed by serving placement (``pipeline_devices`` packs onto the data
axes only).
"""
import subprocess
import sys

import pytest

import conftest

DIFFERENTIAL = r'''
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, f"expected 8 forced devices, got {len(jax.devices())}"
from repro.configs.cnn import CNN_REGISTRY
from repro.core.export import export_cnn, calibrate_exit_threshold
from repro.core.family import CNNFamily
from repro.data import SyntheticImages
from repro.serving import (PipelineParallelScheduler, Request,
                           exit_decisions)
from repro.serving.replica import ChaosPlan
from repro.obs import Tracer, check_trace
from repro.analysis import check as analyze

SLOTS, N = 8, 16
for kind in ('resnet8-cifar', 'vgg8-cifar', 'mobilenet-small-cifar'):
    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[kind].replace(w_bits=8, a_bits=8)
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params,
                                cfg.replace(exit_stages=()),
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    xs = jax.random.normal(jax.random.key(7), (N, 32, 32, 3))
    calib = jax.random.normal(jax.random.key(8), (SLOTS, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=False, calibrate=calib)
    thr = calibrate_exit_threshold(model, calib)
    # synthetic per-stage costs: bit-exactness cannot depend on the
    # simulated clock, only the batches executed on it are real
    costs = [1e-3 * (model.n_stages - k) for k in range(model.n_stages)]
    t = np.cumsum(np.full(N, 2e-4))
    trace = [Request(i, xs[i], float(t[i])) for i in range(N)]
    oracle = {}
    for r in trace:
        xb = jnp.concatenate([r.x[None], jnp.zeros((SLOTS - 1,) + r.x.shape,
                                                   r.x.dtype)])
        logits, exits = model.fn_exits(model.params, xb)
        stage, ans = exit_decisions(logits, exits, thr)
        oracle[r.rid] = (int(stage[0]), np.asarray(ans[0]))
    makespan = None
    for mode, compact, chaos in (('compacting', True, False),
                                 ('static', False, False),
                                 ('chaos', True, True)):
        plan = (ChaosPlan(kills=((0.4 * makespan, None),)) if chaos
                else None)
        tr = Tracer()
        sch = PipelineParallelScheduler(
            model, slots=SLOTS, threshold=thr, stage_costs=costs,
            compact=compact, chaos=plan, tracer=tr)
        comp, met = sch.run_trace(trace)
        assert len(comp) == N, (kind, mode, len(comp))
        for r in trace:
            st, ans = oracle[r.rid]
            c = comp[r.rid]
            assert c.exit_stage == st and np.array_equal(
                np.asarray(c.logits), ans), \
                f"{kind}/{mode}: request {r.rid} diverged from monolithic"
        assert len(set(sch.stage_dev)) > 1, (kind, "placement collapsed")
        v = check_trace(tr, comp)
        assert not v, (kind, mode, v[:4])
        rep = analyze(model=sch.model, x=calib,
                      rules=("placement-consistency",),
                      target=f"{kind}:{mode}")
        assert not [f for f in rep.findings if f.severity == "error"], \
            (kind, mode, [f.message for f in rep.findings])
        if chaos:
            assert any(e[0] == "kill" for e in met.events), \
                (kind, "no kill fired")
            assert sum(e[0] == "placement" for e in met.events) >= 2, \
                (kind, "no re-solve after the kill")
        else:
            assert any(s.name == "transfer.carry" for s in tr.spans), \
                (kind, mode, "no cross-device carry transfer")
        if makespan is None:
            makespan = max(c.t_done for c in comp.values())
        print(f"{kind} {mode}: bit-exact ({len(tr.spans)} spans)")
print("DIFFERENTIAL-OK")
'''


def test_pipeline_bit_exact_all_kinds_forced_8_devices(forced_devices):
    r = forced_devices(DIFFERENTIAL, n=8, timeout=900)
    assert 'DIFFERENTIAL-OK' in r.stdout


MESH = r'''
import jax, numpy as np
assert len(jax.devices()) == 8
from repro.launch.mesh import data_axes
from repro.serving import pipeline_devices, solve_placement
mesh = jax.make_mesh((4, 2), ('data', 'model'))
assert data_axes(mesh) == ('data',)
devs = pipeline_devices(mesh)
assert len(devs) == 4, devs
assert devs == tuple(np.asarray(mesh.devices)[:, 0].reshape(-1))
full = pipeline_devices()
assert len(full) == 8 and set(devs) <= set(full)
pod = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
assert len(pipeline_devices(pod)) == 4          # pod x data, model sliced
p = solve_placement([5.0, 3.0, 2.0, 1.0], len(devs))
assert {d for _, d in p.assignment} <= set(range(4))
assert len({k for k, _ in p.assignment}) == 4   # every stage placed
print("MESH-OK")
'''


def test_mesh_placement_under_forced_devices(forced_devices):
    r = forced_devices(MESH, n=8, timeout=300)
    assert 'MESH-OK' in r.stdout


def test_pipeline_devices_local_mesh():
    import jax

    from repro.launch.mesh import make_local_mesh
    from repro.serving import pipeline_devices
    assert pipeline_devices(make_local_mesh()) == (jax.devices()[0],)
    assert pipeline_devices() == tuple(jax.devices())


def test_registry_plans_multi_model_placement():
    from types import SimpleNamespace

    from repro.serving import ModelRegistry
    reg = ModelRegistry()
    reg.register('a', SimpleNamespace(n_stages=2))
    reg.register('b', SimpleNamespace(n_stages=3))
    p = reg.plan_placement(4, {'a': [4.0, 1.0], 'b': [2.0, 2.0, 1.0]})
    assert len({k for k, _ in p.assignment}) == 5     # every (model, stage)
    assert p.device_of(0, model='a') in range(4)
    assert abs(sum(p.loads) - 10.0) < 1e-9
    with pytest.raises(ValueError, match='missing'):
        reg.plan_placement(4, {'a': [1.0, 1.0]})
    with pytest.raises(ValueError, match='stage'):
        reg.plan_placement(4, {'a': [1.0], 'b': [1.0, 1.0, 1.0]})


def test_registry_place_commits_stage_devices():
    import jax

    from repro.analysis.mutations import _resnet_export
    from repro.serving import ModelRegistry
    model, _, _, x = _resnet_export(use_pallas=False, exits=True)
    reg = ModelRegistry()
    reg.register('cnn', model)
    p = reg.plan_placement(1, {'cnn': [3.0, 2.0, 1.0]})
    placed = reg.place('cnn', p, jax.devices())
    assert placed.stage_devices == (jax.devices()[0],) * model.n_stages
    assert placed.stage_params is not None
    assert reg.get('cnn') is placed          # registry entry re-pointed
    jax.block_until_ready(placed.run_stage(0, x))


def test_force_guard_raises_after_backend_init():
    """The regression the conftest guard exists for: once jax's backend
    is up, forcing a device count must be a loud error, not a silent
    no-op XLA_FLAGS edit."""
    import jax
    jax.devices()                              # ensure the backend is up
    assert conftest.backend_initialized()
    with pytest.raises(RuntimeError, match='already initialized'):
        conftest.force_host_device_count(8)


GUARD = r'''
import sys
sys.path.insert(0, "tests")
import conftest
assert not conftest.backend_initialized()
conftest.force_host_device_count(5)
import jax
assert len(jax.devices()) == 5, len(jax.devices())
assert conftest.backend_initialized()
try:
    conftest.force_host_device_count(6)
except RuntimeError:
    print("GUARD-OK")
else:
    raise SystemExit("guard failed to fire after backend init")
'''


def test_force_guard_subprocess_pre_and_post_init():
    env = conftest.forced_device_env(1)
    env.pop('XLA_FLAGS', None)         # the script forces its own count
    r = subprocess.run([sys.executable, '-c', GUARD], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=conftest.REPO_ROOT)
    assert r.returncode == 0, f'stdout={r.stdout}\nstderr={r.stderr[-2000:]}'
    assert 'GUARD-OK' in r.stdout
