"""Pass-registry + Pipeline API tests: registration round-trips, the
planner stays consistent with the theoretical order over any key set,
chain input validation rejects typos, Q reuses E's stored threshold, and
the low-rank 'L' pass runs and exports end-to-end."""
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import pytest

from repro.configs.cnn import RESNET8_CIFAR
from repro.core import registry
from repro.core.chain import Pipeline, run_chain
from repro.core.family import CNNFamily, LMFamily
from repro.core.passes import (PASSES, ChainState, QuantHP, Trainer,
                               init_chain_state)
from repro.core.planner import (OrderPlanner, compare_orders, pass_rank,
                                theoretical_order)
from repro.data import SyntheticImages, SyntheticTokens

TINY = Trainer(batch=16, steps=2, lr=2e-3, eval_n=1, eval_batch=32)


@pytest.fixture(scope='module')
def cnn_family():
    return CNNFamily(SyntheticImages(difficulty=0.6), image=32)


@pytest.fixture(scope='module')
def tiny_state(cnn_family):
    return init_chain_state(cnn_family, RESNET8_CIFAR, jax.random.key(0),
                            TINY, pretrain_steps=2)


def _copy(state):
    st = replace(state)
    st.history = list(state.history)
    return st


# ----------------------------------------------------------------- registry


def test_registry_round_trip(cnn_family, tiny_state):
    """Register a dummy pass → plan → chain → unregister, via public API
    only (the third-party extension path)."""
    @dataclass(frozen=True)
    class ZHP:
        marker: float = 1.0

    ran = []

    def z_fn(state, hp, trainer):
        assert isinstance(hp, ZHP)
        ran.append(hp.marker)
        return replace(state, key=jax.random.fold_in(state.key, 99))

    registry.register(registry.CompressionPass(
        'Z', 'dummy', 'static', 'neuron', ZHP, z_fn))
    try:
        # plan: Z ties P on (static, neuron) and orders after it by key
        assert theoretical_order() == 'DPZLQE'
        pl = OrderPlanner()
        assert 'Z' in pl.keys
        # chain: typed hps thread through Pipeline.run
        st = Pipeline.from_sequence('Z', {'Z': {'marker': 7.0}}).run(
            cnn_family, None, TINY, state=_copy(tiny_state))
        assert ran == [7.0]
        assert [h['pass'] for h in st.history] == ['baseline', 'Z']
        # the legacy PASSES view sees the new pass
        assert 'Z' in PASSES and PASSES['Z'].name == 'dummy'
    finally:
        registry.unregister('Z')
    with pytest.raises(KeyError):
        registry.get_pass('Z')
    assert theoretical_order() == 'DPLQE'


def test_register_validates_metadata():
    @dataclass(frozen=True)
    class HP:
        x: float = 0.0

    fn = lambda s, h, t: s                                   # noqa: E731
    with pytest.raises(ValueError, match='single uppercase'):
        registry.register(registry.CompressionPass(
            'zz', 'bad', 'static', 'neuron', HP, fn))
    with pytest.raises(ValueError, match='already registered'):
        registry.register(registry.CompressionPass(
            'Q', 'clash', 'static', 'neuron', HP, fn))
    with pytest.raises(ValueError, match='unknown kind'):
        registry.register(registry.CompressionPass(
            'Y', 'bad', 'adaptive', 'neuron', HP, fn))

    @dataclass(frozen=True)
    class NoDefault:
        x: float

    with pytest.raises(ValueError, match='needs a default'):
        registry.register(registry.CompressionPass(
            'Y', 'bad', 'static', 'neuron', NoDefault, fn))
    assert registry.check_consistency() == ('D', 'E', 'L', 'P', 'Q')


def test_hp_typo_rejected():
    with pytest.raises(TypeError, match='unknown hyperparameters'):
        registry.get_pass('Q').resolve_hp({'w_bit': 4})
    # typed dataclasses pass through untouched
    hp = QuantHP(w_bits=4, a_bits=8)
    assert registry.get_pass('Q').resolve_hp(hp) is hp


# ------------------------------------------------------------------ planner


def test_theoretical_order_matches_planner_toposort_5pass():
    """Acceptance: theoretical_order('DPLQE') == topo-sort of the pairwise
    DAG built from the theoretical principles over all 5 passes."""
    pl = OrderPlanner()
    for a, b in pl.pairs():
        pl.add_pairwise(a, b, 'AB' if pass_rank(a) <= pass_rank(b) else 'BA')
    assert pl.topological_order() == theoretical_order('DPLQE') == 'DPLQE'


def test_compare_orders_tie_falls_back_to_theory():
    same = [(0.9, 10.0), (0.8, 40.0)]
    w, sa, sb = compare_orders(same, list(same), 'L', 'Q')
    assert (w, sa) == ('AB', sb)          # L ranks before Q
    w, _, _ = compare_orders(same, list(same), 'Q', 'L')
    assert w == 'BA'
    w, _, _ = compare_orders(same, list(same))    # legacy: no keys given
    assert w == 'AB'


def test_resolve_cycles_drops_zero_margin_first():
    pl = OrderPlanner('DPQ')
    pl.add_pairwise('D', 'P', 'AB', margin=0.5)
    pl.add_pairwise('P', 'Q', 'AB', margin=0.5)
    pl.add_pairwise('D', 'Q', 'BA', margin=0.0)   # tied edge flips the order
    dropped = pl.resolve_cycles()
    assert dropped == [('Q', 'D')]
    assert pl.topological_order() == 'DPQ'


# ----------------------------------------------------------- chain inputs


def test_pipeline_rejects_duplicates_and_strays():
    with pytest.raises(ValueError, match='duplicate'):
        Pipeline.from_sequence('DQQ')
    assert Pipeline.from_sequence('QQ', allow_repeats=True).sequence == 'QQ'
    with pytest.raises(ValueError, match="not in sequence"):
        Pipeline.from_sequence('DQ', {'Q8': {'w_bits': 8}})
    with pytest.raises(KeyError, match='unknown pass'):
        Pipeline.from_sequence('DX')
    with pytest.raises(ValueError, match='empty'):
        Pipeline.from_sequence('')


def test_pipeline_auto_follows_planner():
    pl = OrderPlanner('PQ')
    pl.add_pairwise('P', 'Q', 'BA')               # deliberately anti-theory
    assert Pipeline.auto(pl).sequence == 'QP'
    assert Pipeline.auto({'topological_order': 'PQ'}).sequence == 'PQ'


# ------------------------------------------------- Q reuses E's threshold


def test_quantize_reuses_stored_exit_threshold(monkeypatch):
    fam = CNNFamily(SyntheticImages(difficulty=0.6), image=32)
    params = fam.init(jax.random.key(0), RESNET8_CIFAR)
    params, cfg = fam.add_exits(jax.random.key(1), params, RESNET8_CIFAR,
                                fam.default_exit_points(RESNET8_CIFAR))
    seen = []
    real = fam.exit_stats
    monkeypatch.setattr(
        fam, 'exit_stats',
        lambda p, c, batches, thr: seen.append(thr) or real(p, c, batches,
                                                            thr))
    st = ChainState(family=fam, cfg=cfg, params=params,
                    key=jax.random.key(2), exit_probs={0: 0.5},
                    exit_threshold=0.33, dyn_accuracy=0.5)
    st2 = PASSES['Q'].apply(st, {'w_bits': 8, 'a_bits': 8}, TINY)
    assert seen == [0.33]                 # E's operating point, not Q's hp
    assert st2.exit_threshold == 0.33
    # Q no longer accepts a threshold of its own
    with pytest.raises(TypeError, match='unknown hyperparameters'):
        PASSES['Q'].apply(st, {'threshold': 0.9}, TINY)


def test_early_exit_records_threshold(cnn_family, tiny_state):
    st = PASSES['E'].apply(_copy(tiny_state), {'threshold': 0.42}, TINY)
    assert st.exit_threshold == 0.42


# --------------------------------------------------------- low-rank pass


def test_lowrank_chain_runs_and_exports(cnn_family, tiny_state):
    from repro.core.export import export_chain
    st = run_chain(cnn_family, None, 'LQ',
                   {'L': {'energy': 0.5, 'min_rank': 2},
                    'Q': {'w_bits': 8, 'a_bits': 8}},
                   TINY, state=_copy(tiny_state))
    assert [h['pass'] for h in st.history] == ['baseline', 'L', 'Q']
    assert 0 < st.lowrank_scale < 1.0     # factorization saved stage MACs
    assert any('u' in blk[k] for blocks in st.params['stages']
               for blk in blocks for k in blk if isinstance(blk[k], dict))
    assert st.history[-1]['CR'] > st.history[0]['CR']
    model = export_chain(st)
    out = model.serve(jnp.zeros((2, 32, 32, 3)))
    assert out.shape == (2, st.cfg.num_classes)
    assert bool(jnp.isfinite(out).all())


def test_lowrank_registered_without_touching_core_consumers():
    """'L' came in purely through registration: chain and planner handle it
    with no key-specific branches."""
    p = registry.get_pass('L')
    assert (p.kind, p.granularity) == ('static', 'sub-neuron')
    assert theoretical_order('LQ') == 'LQ'
    assert Pipeline.from_sequence('DPLQE').sequence == 'DPLQE'


def test_lm_factorize_stacked_and_prune_guard():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config('tinyllama-1.1b', layers=4).replace(
        vocab_size=128)
    fam = LMFamily(SyntheticTokens(vocab=cfg.vocab_size), seq=32)
    params = fam.init(jax.random.key(0), cfg)
    fp, fcfg, scale = fam.factorize(params, cfg, energy=0.3, min_rank=2)
    assert scale < 1.0
    assert 'u' in fp['blocks'][0]['mlp']['wi']    # stacked scan group
    batch = fam.train_batch(jax.random.key(1), 2)
    assert bool(jnp.isfinite(fam.logits_of(fp, fcfg, batch)).all())
    # bitops picks the weight-volume scale up
    assert fam.bitops(fcfg, None, scale) < fam.bitops(fcfg)
    # P after L is rejected with a clear message (sequence-law order)
    with pytest.raises(ValueError, match='P before L'):
        fam.prune(fp, fcfg, 0.3)


def test_cnn_prune_guard_after_factorize(cnn_family):
    params = cnn_family.init(jax.random.key(0), RESNET8_CIFAR)
    fp, _, _ = cnn_family.factorize(params, RESNET8_CIFAR, energy=0.5,
                                    min_rank=2)
    with pytest.raises(ValueError, match='P before L'):
        cnn_family.prune(fp, RESNET8_CIFAR, 0.3)


# ------------------------------------------------------- serving backends


def test_export_chain_unregistered_family_raises():
    from repro.core.export import export_chain

    class AlienFamily:
        pass

    st = ChainState(family=AlienFamily(), cfg=None, params={},
                    key=jax.random.key(0))
    with pytest.raises(KeyError, match='no serving backend'):
        export_chain(st)


def test_serving_backend_mro_lookup():
    from repro.core.export import serving_backend_for

    class MyCNNFamily(CNNFamily):
        pass

    fam = MyCNNFamily(SyntheticImages())
    assert callable(serving_backend_for(fam))     # inherits CNN backend
