"""Hypothesis property tests for the Pallas kernels.

Kept separate from test_kernels.py so the shape/dtype sweeps still collect
when hypothesis is not installed (the dep lives in requirements-dev.txt).
"""
import pytest

pytest.importorskip('hypothesis')

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from hypothesis import given, settings, strategies as st     # noqa: E402

from repro.kernels import ref                                # noqa: E402
from repro.kernels.decode_attention import decode_attention  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_fake_quant_properties(bits, seed):
    """Idempotence + bounded error + level count <= 2^bits."""
    w = jax.random.normal(jax.random.key(seed), (64, 64))
    q1 = ref.fake_quant_ref(w, bits)
    q2 = ref.fake_quant_ref(q1, bits)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-5, atol=1e-6)     # idempotent
    qmax = 2.0 ** (bits - 1) - 1
    scale = np.abs(np.asarray(w)).max(0) / qmax
    err = np.abs(np.asarray(q1 - w))
    assert (err <= 0.5 * scale[None, :] + 1e-6).all()    # half-step bound
    for col in range(0, 64, 16):
        levels = np.unique(np.asarray(q1[:, col]))
        assert len(levels) <= 2 ** bits


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       frac=st.floats(0.1, 1.0))
def test_decode_attention_mask_property(seed, frac):
    """Output must equal attention computed only over the valid prefix."""
    B, H, K, D, S = 1, 4, 2, 32, 256
    k = jax.random.key(seed)
    q = jax.random.normal(k, (B, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    n = max(1, int(S * frac))
    valid = jnp.arange(S) < n
    out = decode_attention(q, kk, vv, valid, s_blk=64, interpret=True)
    trunc = ref.decode_attention_ref(q, kk[:, :n], vv[:, :n],
                                     jnp.ones((B, n), bool))
    np.testing.assert_allclose(np.asarray(out), np.asarray(trunc),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), c=st.integers(1, 40),
       mult=st.sampled_from([1, 2, 4]), stride=st.sampled_from([1, 2]))
def test_depthwise_conv_bit_exact_property(seed, c, mult, stride):
    """For ANY channel count / multiplier / stride, the direct depthwise
    kernel is bit-identical to the lax.conv oracle on raw integer codes."""
    from repro.kernels.depthwise_conv import depthwise_conv
    k = jax.random.key(seed)
    n = c * mult
    x = jax.random.randint(k, (1, 7, 8, c), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(k, 1), (3, 3, 1, n),
                           -128, 128, jnp.int8)
    sw = jax.random.uniform(jax.random.fold_in(k, 2), (n,), jnp.float32,
                            1e-3, 1e-2)
    out = depthwise_conv(x, w, 0.01, sw, None, stride=stride,
                         out_scale=0.05, interpret=True)
    expect = ref.depthwise_conv_ref(x, w, 0.01, sw, None, stride=stride,
                                    out_scale=0.05)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
