"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting shapes and finiteness; plus prefill->decode
consistency against the full forward (the strongest cheap invariant of the
serving path)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import adamw, apply_updates


def make_batch(cfg, B=2, S=32, key=0):
    k = jax.random.key(key)
    batch = {'tokens': jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.arch_kind == 'vlm':
        batch['patches'] = 0.02 * jax.random.normal(
            k, (B, cfg.frontend_tokens, cfg.d_model))
    if cfg.arch_kind == 'encdec':
        batch['frames'] = 0.02 * jax.random.normal(
            k, (B, cfg.frontend_tokens, cfg.d_model))
    batch['labels'] = jax.random.randint(jax.random.fold_in(k, 1),
                                         (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize('arch', ARCH_NAMES)
def test_forward_shapes_finite(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits = m.forward(params, batch)
    n_front = cfg.frontend_tokens if cfg.arch_kind == 'vlm' else 0
    assert logits.shape == (B, S + n_front, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize('arch', ARCH_NAMES)
def test_train_step_improves_nothing_breaks(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            lg = m.forward(p, batch)
            lg = lg[:, -batch['labels'].shape[1]:]
            lp = jax.nn.log_softmax(lg.astype(jnp.float32))
            return -jnp.mean(jnp.take_along_axis(
                lp, batch['labels'][..., None], -1))
        l, g = jax.value_and_grad(loss_fn)(params)
        up, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, up), opt_state, l

    l0 = None
    for _ in range(3):
        params, opt_state, l = step(params, opt_state)
        assert bool(jnp.isfinite(l)), arch
        l0 = l0 if l0 is not None else float(l)
    assert float(l) < l0 + 1e-3, f'{arch}: loss exploded {l0}->{float(l)}'


@pytest.mark.parametrize('arch', ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    _, cache = m.prefill(params, batch, max_len=64)
    tok = jnp.full((B,), 7, jnp.int32)
    enc = m.encode(params, batch['frames']) if cfg.arch_kind == 'encdec' \
        else None
    n_front = cfg.frontend_tokens if cfg.arch_kind == 'vlm' else 0
    lg_dec, _ = m.decode_step(params, tok, jnp.asarray(S + n_front,
                                                       jnp.int32),
                              cache, enc=enc)
    batch2 = dict(batch,
                  tokens=jnp.concatenate([batch['tokens'], tok[:, None]], 1))
    lg_full = m.forward(params, batch2)[:, -1]
    err = float(jnp.max(jnp.abs(lg_dec - lg_full)))
    # MoE capacity dropping is batch-context dependent -> looser tolerance
    tol = 1.5 if cfg.is_moe else 1e-4
    assert err < tol, f'{arch}: decode diverges from forward by {err}'


def test_full_configs_match_assignment():
    """Pin the published numbers so a refactor can't drift them."""
    c = get_config('qwen2-72b')
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    assert c.qkv_bias
    c = get_config('deepseek-v3-671b')
    assert (c.num_layers, c.d_model, c.num_heads, c.n_experts, c.top_k,
            c.moe_d_ff, c.vocab_size) == (61, 7168, 128, 256, 8, 2048,
                                          129280)
    assert c.use_mla and c.n_shared_experts == 1
    c = get_config('mamba2-2.7b')
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (64, 2560, 128, 50280)
    c = get_config('gemma2-9b')
    assert (c.num_layers, c.d_model, c.logit_softcap) == (42, 3584, 30.0)
    assert c.block_pattern == ('local', 'global')
    c = get_config('gemma3-12b')
    assert c.block_pattern.count('local') == 5
    c = get_config('recurrentgemma-9b')
    assert c.block_pattern == ('recurrent', 'recurrent', 'local')
    c = get_config('mixtral-8x7b')
    assert (c.n_experts, c.top_k, c.window) == (8, 2, 4096)
    c = get_config('whisper-small')
    assert c.arch_kind == 'encdec' and not c.shard_heads
    c = get_config('internvl2-2b')
    assert c.arch_kind == 'vlm' and c.vocab_size == 92553
    c = get_config('tinyllama-1.1b')
    assert (c.num_layers, c.num_kv_heads) == (22, 4)
