"""Fused low-rank conv kernel tests: one Pallas launch for a factored
(u, v) conv pair, bit-exact with the chained two-launch int8-resident path
(shared int32 accumulation domain + identical fp32 epilogue op order), and
matching the fp32 lax.conv reference on dequantized operands.

Ranks exercised: r=1 and r=7 (prime — both force zero-padding of the rank
dim to the 128 lane, which must be value-exact), and r=128 (a full MXU
tile, no padding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lowrank_conv import fits_fused, lowrank_conv
from repro.kernels.quant_conv import quant_conv


def _factored_case(r, cin=16, cout=32, seed=0):
    k = jax.random.key(seed)
    x = jax.random.normal(k, (2, 8, 8, cin))
    u = jax.random.normal(jax.random.fold_in(k, 1), (3, 3, cin, r)) * 0.1
    v = jax.random.normal(jax.random.fold_in(k, 2), (1, 1, r, cout)) * 0.1
    bu = jax.random.normal(jax.random.fold_in(k, 3), (r,)) * 0.1
    bv = jax.random.normal(jax.random.fold_in(k, 4), (cout,)) * 0.1
    u_q, su = ops.prequantize_weight(u)
    v_q, sv = ops.prequantize_weight(v)
    x_q, sx = ops.quantize_act(x)
    return x_q, u_q, v_q, su, sv, bu, bv, float(sx)


@pytest.mark.parametrize('r', [1, 7, 128])
@pytest.mark.parametrize('stride,relu,out_scale', [(1, False, None),
                                                   (2, True, 0.031)])
def test_fused_bit_exact_with_two_launch_path(r, stride, relu, out_scale):
    """ONE fused launch == quant_conv(u, out_scale=h) -> quant_conv(v),
    bit-for-bit: same int32 accumulators, same requantized intermediate,
    same epilogue — for fp32 and int8 (requantize) outputs alike."""
    x_q, u_q, v_q, su, sv, bu, bv, sx = _factored_case(r)
    h_scale = 0.05
    fused = lowrank_conv(x_q, u_q, v_q, su, sv, bu, bv, sx=sx,
                         h_scale=h_scale, stride=stride, relu=relu,
                         out_scale=out_scale, interpret=True)
    h = quant_conv(x_q, u_q, sx, su, bu, stride=stride, out_scale=h_scale,
                   interpret=True)
    chained = quant_conv(h, v_q, h_scale, sv, bv, relu=relu,
                         out_scale=out_scale, interpret=True)
    assert fused.dtype == (jnp.int8 if out_scale else jnp.float32)
    assert fused.shape == chained.shape
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(chained))


@pytest.mark.parametrize('r', [1, 7, 128])
def test_fused_matches_fp32_lax_conv_reference(r):
    """Fused kernel tracks the fp32 conv chain on dequantized operands
    (conv-of-dequant == dequant-of-int32-accum up to the requantized
    intermediate's grid)."""
    x_q, u_q, v_q, su, sv, bu, bv, sx = _factored_case(r)
    h_scale = 0.05
    fused = lowrank_conv(x_q, u_q, v_q, su, sv, bu, bv, sx=sx,
                         h_scale=h_scale, interpret=True)
    x = x_q.astype(jnp.float32) * sx
    u = u_q.astype(jnp.float32) * su[None, None, None, :]
    h = jax.lax.conv_general_dilated(
        x, u, (1, 1), 'SAME', dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    h = h + bu
    # the fused kernel quantizes the rank intermediate to the static grid
    h = jnp.clip(jnp.round(h / h_scale), -128, 127) * h_scale
    v = v_q.astype(jnp.float32) * sv[None, None, None, :]
    expect = jax.lax.conv_general_dilated(
        h, v, (1, 1), 'SAME', dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    expect = expect + bv
    np.testing.assert_allclose(np.asarray(fused), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_fused_equals_ref_fallback():
    """ops.lowrank_conv_nhwc: Pallas (interpret) and the jnp reference
    fallback produce identical int8 outputs — the CPU serving path and the
    TPU kernel sit on the same requantize grids."""
    x_q, u_q, v_q, su, sv, bu, bv, sx = _factored_case(7)
    kw = dict(sx=sx, h_scale=0.05, stride=1, relu=True, out_scale=0.02)
    a = ops.lowrank_conv_nhwc(x_q, u_q, v_q, su, sv, bu, bv,
                              use_pallas=True, **kw)
    b = ops.lowrank_conv_nhwc(x_q, u_q, v_q, su, sv, bu, bv,
                              use_pallas=False, **kw)
    assert a.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _count_pallas_calls(jaxpr):
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == 'pallas_call':
            n += 1
        for v in eqn.params.values():
            if hasattr(v, 'jaxpr'):
                n += _count_pallas_calls(v.jaxpr)
            elif hasattr(v, 'eqns'):
                n += _count_pallas_calls(v)
    return n


def test_fused_is_one_launch_chained_is_two():
    """The whole point: a factored conv pair costs ONE pallas_call in the
    fused lowering and two in the chained lowering."""
    x_q, u_q, v_q, su, sv, bu, bv, sx = _factored_case(7)

    def fused(xq):
        return ops.lowrank_conv_nhwc(xq, u_q, v_q, su, sv, bu, bv, sx=sx,
                                     h_scale=0.05, use_pallas=True)

    def chained(xq):
        h = ops.quant_conv_static(xq, u_q, su, bu, sx=sx, out_scale=0.05,
                                  use_pallas=True)
        return ops.quant_conv_static(h, v_q.reshape(1, 1, 7, 32), sv, bv,
                                     sx=0.05, use_pallas=True)

    assert _count_pallas_calls(jax.make_jaxpr(fused)(x_q).jaxpr) == 1
    assert _count_pallas_calls(jax.make_jaxpr(chained)(x_q).jaxpr) == 2


def test_fits_fused_envelope():
    """Fused eligibility is rank-only: r within one padded 128 lane tile.
    COUT is a grid axis now, so arbitrary widths fit; larger ranks chain
    instead of silently spilling VMEM."""
    assert fits_fused(1, 64) and fits_fused(7, 512) and fits_fused(128, 512)
    assert not fits_fused(129, 64)          # rank crosses the 128 lane tile
    assert fits_fused(64, 1 << 20)          # any COUT: N axis is gridded


def test_fused_wide_cout_multi_n_tile():
    """COUT wider than one lane tile exercises the N grid axis + persistent
    h scratch: still bit-exact with the chained path."""
    x_q, u_q, v_q, su, sv, bu, bv, sx = _factored_case(7, cout=384)
    h_scale = 0.05
    fused = lowrank_conv(x_q, u_q, v_q, su, sv, bu, bv, sx=sx,
                         h_scale=h_scale, interpret=True)
    h = quant_conv(x_q, u_q, sx, su, bu, out_scale=h_scale, interpret=True)
    chained = quant_conv(h, v_q, h_scale, sv, bv, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(chained))


def test_lowering_costs_geometry():
    """The analytic cost model reflects the real trade: fused saves a launch
    and the h round-trip, chained flushes each output block once.  For a
    small factored layer fused must win; blowing up the K axis (many output
    reflushes) must eventually favor chained — and MACs agree always."""
    from repro.kernels.lowrank_conv import lowering_costs
    small = lowering_costs(m=2 * 8 * 8, k1=3 * 3 * 16, r=7, n=32)
    assert small['fused_us'] < small['chained_us']
    big = lowering_costs(m=1 << 14, k1=1 << 16, r=7, n=1 << 12)
    assert big['chained_us'] < big['fused_us']
    for c in (small, big):
        assert c['macs'] > 0 and c['fused_bytes'] > 0
