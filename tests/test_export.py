"""Export-and-serve subsystem tests: the compiled int8 path must match the
fake-quant QAT oracle, compute no per-call weight scales, and the new
quant_conv kernel must match its lax.conv oracle in interpret mode.
The int8-resident plan (``calibrate=...``) additionally must keep
inter-layer activations int8 at every kernel boundary, never run an
activation abs-max, and serve factored conv pairs as single launches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import prim_count, walk_eqns
from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                               VGG8_CIFAR)
from repro.core import quantization as quant_lib
from repro.core.export import early_exit_batch, export_chain, export_cnn
from repro.core.family import CNNFamily
from repro.core.passes import ChainState
from repro.data import SyntheticImages
from repro.kernels import ops, ref
from repro.kernels.quant_conv import im2col_nhwc, quant_conv
from repro.kernels.tiling import fit_block, fit_or_pad, pad_to
from repro.models.cnn import cnn_forward, init_cnn

CONFIGS = {'resnet': RESNET8_CIFAR, 'vgg': VGG8_CIFAR,
           'mobilenet': MOBILENET_SMALL_CIFAR}


def _with_exits(base, key=2):
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), base)
    params, cfg = fam.add_exits(jax.random.key(key), params, base,
                                fam.default_exit_points(base))
    return fam, params, cfg.replace(w_bits=8, a_bits=8)


# ------------------------------------------------------------ exported path


@pytest.mark.parametrize('kind', sorted(CONFIGS))
def test_export_matches_fake_quant_oracle(kind):
    """Exported int8 serving == fake-quant fp32 forward (same quant grids,
    bilinear kernels) up to fp32 accumulation noise, incl. exit heads."""
    _, params, cfg = _with_exits(CONFIGS[kind])
    x = jax.random.normal(jax.random.key(1), (8, 32, 32, 3))
    oracle, oracle_exits = jax.jit(
        lambda p, x: cnn_forward(p, cfg, x, collect_exits=True))(params, x)
    model = export_cnn(params, cfg)
    served, served_exits = model.fn_exits(model.params, x)
    scale = float(jnp.max(jnp.abs(oracle)))
    np.testing.assert_allclose(np.asarray(served), np.asarray(oracle),
                               atol=1e-3 * max(scale, 1.0))
    assert set(served_exits) == set(oracle_exits)
    for s in oracle_exits:
        np.testing.assert_allclose(np.asarray(served_exits[s]),
                                   np.asarray(oracle_exits[s]), atol=1e-3)


def test_export_pallas_matches_jnp_path():
    """Pallas interpret-mode serving == the jnp int8 reference serving."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    m_ref = export_cnn(params, cfg, use_pallas=False)
    m_pls = export_cnn(params, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(m_pls.serve(x)),
                               np.asarray(m_ref.serve(x)),
                               rtol=1e-4, atol=1e-4)


def test_export_low_bit_chain_cfg():
    """Chain-style cfg (w_bits=4, a_bits=8) exports on the 4-bit grid."""
    cfg = VGG8_CIFAR.replace(w_bits=4, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
    oracle = jax.jit(lambda p, x: cnn_forward(p, cfg, x))(params, x)
    served = export_cnn(params, cfg).serve(x)
    scale = float(jnp.max(jnp.abs(oracle)))
    np.testing.assert_allclose(np.asarray(served), np.asarray(oracle),
                               atol=1e-3 * max(scale, 1.0))
    # 4-bit grid: stored int8 values stay within [-8, 7]
    leaves = [v for v in jax.tree_util.tree_leaves(
        export_cnn(params, cfg).params) if v.dtype == jnp.int8]
    assert leaves and all(int(jnp.max(jnp.abs(v))) <= 8 for v in leaves)


def test_export_binary_weights_finite():
    """w_bits=1 (DoReFa sign*mean) exports without inf scales / NaN logits
    — all serving quantizers route through quantize_weight's bits=1
    branch."""
    cfg = VGG8_CIFAR.replace(w_bits=1, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    model = export_cnn(params, cfg)
    served = model.serve(jnp.ones((2, 32, 32, 3)))
    assert bool(jnp.all(jnp.isfinite(served)))
    ints = [v for v in jax.tree_util.tree_leaves(model.params)
            if v.dtype == jnp.int8]
    assert ints and all(int(jnp.max(jnp.abs(v))) <= 1 for v in ints)


def test_export_static_weight_scales():
    """Tracing the serving fn computes NO weight scales; tracing the
    fake-quant forward computes one per weight (the per-call recompute the
    export pass eliminates)."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    model = export_cnn(params, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))

    before = quant_lib.WEIGHT_SCALE_COMPUTATIONS[0]
    jax.make_jaxpr(lambda x: model.fn(model.params, x))(x)
    assert quant_lib.WEIGHT_SCALE_COMPUTATIONS[0] == before

    jax.make_jaxpr(lambda x: cnn_forward(params, cfg, x))(x)
    assert quant_lib.WEIGHT_SCALE_COMPUTATIONS[0] > before


def test_export_chain_dispatch():
    fam, params, cfg = _with_exits(RESNET8_CIFAR)
    st = ChainState(family=fam, cfg=cfg, params=params,
                    key=jax.random.key(0))
    model = export_chain(st)
    assert model.fn_exits is not None
    out = model.serve(jnp.zeros((2, 32, 32, 3)))
    assert out.shape == (2, cfg.num_classes)


# --------------------------------------------------- low-rank factored path


def _with_factored_exits(base, energy=0.6):
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), base)
    params, _, scale = fam.factorize(params, base, energy=energy, min_rank=2)
    assert scale < 1.0                    # something actually factored
    params, cfg = fam.add_exits(jax.random.key(2), params, base,
                                fam.default_exit_points(base))
    return fam, params, cfg.replace(w_bits=8, a_bits=8)


@pytest.mark.parametrize('kind', ['resnet', 'vgg'])
def test_export_factored_matches_fake_quant_oracle(kind):
    """A chain containing 'L' (low-rank u/v conv pairs + factored head fc)
    exports to int8 serving that matches the fake-quant forward — the
    factored dispatch is identical in QAT (models/cnn.py) and serving
    (core/export.py), incl. exit heads hung off factored blocks."""
    _, params, cfg = _with_factored_exits(CONFIGS[kind])
    x = jax.random.normal(jax.random.key(1), (8, 32, 32, 3))
    oracle, oracle_exits = jax.jit(
        lambda p, x: cnn_forward(p, cfg, x, collect_exits=True))(params, x)
    model = export_cnn(params, cfg)
    served, served_exits = model.fn_exits(model.params, x)
    scale = float(jnp.max(jnp.abs(oracle)))
    np.testing.assert_allclose(np.asarray(served), np.asarray(oracle),
                               atol=2e-3 * max(scale, 1.0))
    for s in oracle_exits:
        np.testing.assert_allclose(np.asarray(served_exits[s]),
                                   np.asarray(oracle_exits[s]), atol=2e-3)


def test_export_factored_pallas_matches_jnp_path():
    """Factored convs route twice through the kernels: interpret-mode
    Pallas serving == the jnp int8 reference serving."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), cfg)
    params, _, _ = fam.factorize(params, cfg, energy=0.6, min_rank=2)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    m_ref = export_cnn(params, cfg, use_pallas=False)
    m_pls = export_cnn(params, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(m_pls.serve(x)),
                               np.asarray(m_ref.serve(x)),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------- int8-resident serving


# jaxpr walking comes from the shared analyzer walker (repro/analysis) —
# the SAME implementation the production rules enforce contracts with, so
# what these tests count and what the CI gate checks can never drift apart


@pytest.mark.parametrize('kind', sorted(CONFIGS))
def test_export_resident_matches_fake_quant_oracle(kind):
    """The int8-resident plan (static scales, requantize epilogues) tracks
    the fake-quant oracle.  Looser tolerance than the dynamic path: the
    resident graph quantizes conv *outputs* too (that is what keeps them
    int8 in HBM), one extra rounding per layer."""
    _, params, cfg = _with_exits(CONFIGS[kind])
    x = jax.random.normal(jax.random.key(1), (8, 32, 32, 3))
    oracle, oracle_exits = jax.jit(
        lambda p, x: cnn_forward(p, cfg, x, collect_exits=True))(params, x)
    model = export_cnn(params, cfg, calibrate=x)
    served, served_exits = model.fn_exits(model.params, x)
    scale = float(jnp.max(jnp.abs(oracle)))
    np.testing.assert_allclose(np.asarray(served), np.asarray(oracle),
                               atol=6e-2 * max(scale, 1.0))
    assert set(served_exits) == set(oracle_exits)
    assert model.summary()['n_layers'] > 0


def test_export_resident_pallas_matches_jnp_path():
    """Interpret-mode Pallas resident serving tracks the jnp resident
    serving.  The backends share every *inter-layer* static grid but differ
    by design inside a layer: Pallas kernels requantize their outputs at
    the HBM boundary, the CPU lowering carries fp32 from conv to its own
    glue (no int8 conv units to feed) — so parity is within per-layer
    quantization noise, not bit-exact."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    m_ref = export_cnn(params, cfg, use_pallas=False, calibrate=x)
    m_pls = export_cnn(params, cfg, use_pallas=True, calibrate=x)
    ref_out = np.asarray(m_ref.serve(x))
    scale = float(np.max(np.abs(ref_out)))
    np.testing.assert_allclose(np.asarray(m_pls.serve(x)), ref_out,
                               atol=4e-2 * max(scale, 1.0))


def test_export_resident_no_dynamic_activation_scales():
    """The resident jaxpr contains ZERO reduce_max ops — no activation
    abs-max ever runs at serve time (weight scales were already static;
    now activation scales are too).  The dynamic path runs one per layer."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))

    m_dyn = export_cnn(params, cfg)
    m_res = export_cnn(params, cfg, calibrate=x)
    dyn = jax.make_jaxpr(lambda x: m_dyn.fn(m_dyn.params, x))(x)
    res = jax.make_jaxpr(lambda x: m_res.fn(m_res.params, x))(x)
    assert prim_count(dyn.jaxpr, 'reduce_max') > 0
    assert prim_count(res.jaxpr, 'reduce_max') == 0

    before = quant_lib.WEIGHT_SCALE_COMPUTATIONS[0]
    jax.make_jaxpr(lambda x: m_res.fn(m_res.params, x))(x)
    assert quant_lib.WEIGHT_SCALE_COMPUTATIONS[0] == before


@pytest.mark.parametrize('kind', sorted(CONFIGS))
def test_export_resident_int8_at_kernel_boundaries(kind):
    """Dtype-trace the resident Pallas serving fn: every kernel consumes
    int8 activations and every kernel output is int8, except the fp32
    logit heads (head + exit fcs).  With the depthwise kernel serving
    mobilenet's grouped convs there is NO fp32 conv left in the graph —
    zero fallback, zero fp32 MACs (the fallback exemption is gone)."""
    _, params, cfg = _with_exits(CONFIGS[kind])
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=True, calibrate=x)
    jaxpr = jax.make_jaxpr(
        lambda p, x: model.fn_exits(p, x))(model.params, x)
    calls = [e for e in walk_eqns(jaxpr.jaxpr)
             if e.primitive.name == 'pallas_call']
    assert calls, 'resident export must route through Pallas kernels'
    for e in calls:
        assert e.invars[0].aval.dtype == jnp.int8   # int8 activations in
    out_dtypes = [v.aval.dtype for e in calls for v in e.outvars]
    n_fp32 = sum(1 for d in out_dtypes if d == jnp.float32)
    n_heads = 1 + len(model.cfg.exit_stages)        # final + exit logits
    assert n_fp32 == n_heads, (n_fp32, n_heads)
    assert all(d in (jnp.int8, jnp.float32) for d in out_dtypes)
    # zero fp32 convs in the resident graph — every conv (incl. mobilenet
    # depthwise) runs an int8 Pallas kernel
    assert model.summary()['n_fallback'] == 0
    n_fp32_convs = sum(
        1 for e in walk_eqns(jaxpr.jaxpr)
        if e.primitive.name == 'conv_general_dilated'
        and e.outvars[0].aval.dtype == jnp.float32)
    assert n_fp32_convs == 0, n_fp32_convs


def test_export_resident_factored_single_launch():
    """A factored (u, v) conv layer serves as exactly ONE Pallas launch in
    the resident plan; total pallas_call count matches the plan's
    kernel-launch accounting."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), cfg)
    params, _, _ = fam.factorize(params, cfg, energy=0.6, min_rank=2)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    model = export_cnn(params, cfg, use_pallas=True, calibrate=x)
    s = model.summary()
    assert s['n_fused_lowrank'] > 0
    jaxpr = jax.make_jaxpr(lambda p, x: model.fn(p, x))(model.params, x)
    assert prim_count(jaxpr.jaxpr, 'pallas_call') == s['kernel_launches']
    # exit-head launches are accounted separately: fn excludes them,
    # fn_exits adds exactly that many
    fam2, eparams, ecfg = _with_exits(RESNET8_CIFAR)
    em = export_cnn(eparams, ecfg, use_pallas=True, calibrate=x)
    es = em.summary()
    assert es['n_exit_heads'] == len(ecfg.exit_stages) > 0
    jx_fn = jax.make_jaxpr(lambda p, x: em.fn(p, x))(em.params, x)
    jx_ex = jax.make_jaxpr(lambda p, x: em.fn_exits(p, x))(em.params, x)
    assert prim_count(jx_fn.jaxpr, 'pallas_call') == es['kernel_launches']
    assert prim_count(jx_ex.jaxpr, 'pallas_call') == \
        es['kernel_launches'] + es['exit_head_launches']
    # and the oracle still holds through the fused kernels
    oracle = jax.jit(lambda p, x: cnn_forward(p, cfg, x))(params, x)
    served = export_cnn(params, cfg, use_pallas=False, calibrate=x).serve(x)
    scale = float(jnp.max(jnp.abs(oracle)))
    np.testing.assert_allclose(np.asarray(served), np.asarray(oracle),
                               atol=6e-2 * max(scale, 1.0))


def test_export_resident_fallback_mac_fraction():
    """Mobilenet's depthwise convs serve on the int8 depthwise kernel now:
    the declared-fallback MAC share the summary used to report (~21%) is
    exactly zero, and the plan counts the layers as depthwise instead."""
    cfg = MOBILENET_SMALL_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    s = export_cnn(params, cfg, calibrate=x).summary()
    assert s['n_fallback'] == 0
    assert s['fallback_mac_fraction'] == 0.0
    assert s['n_depthwise'] > 0
    # resnet has no grouped convs at all
    cfg_r = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    s_r = export_cnn(init_cnn(jax.random.key(0), cfg_r), cfg_r,
                     calibrate=x).summary()
    assert s_r['fallback_mac_fraction'] == 0.0
    assert s_r['n_depthwise'] == 0


def test_export_kernel_selection_recorded():
    """Every factored conv's plan entry records the fused-vs-chained
    decision with costs and a reason; 'model' (default) never contradicts
    the analytic model, 'fused'/fuse_lowrank=False force the lowerings."""
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), cfg)
    params, _, _ = fam.factorize(params, cfg, energy=0.6, min_rank=2)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    s = export_cnn(params, cfg, calibrate=x).summary()
    sels = s['lowrank_selection']
    assert sels, 'factored export must record selections'
    for name, sel in sels.items():
        assert sel['choice'] in ('fused', 'chained')
        assert sel['why']
        if 'fused_us' in sel:   # modeled: choice must match the costs
            modeled = ('fused' if sel['fused_us'] <= sel['chained_us']
                       else 'chained')
            assert sel['choice'] == modeled, (name, sel)
    forced = export_cnn(params, cfg, calibrate=x,
                        fuse_lowrank=False).summary()
    assert all(v['choice'] == 'chained'
               for v in forced['lowrank_selection'].values())
    assert forced['n_fused_lowrank'] == 0
    pinned = export_cnn(params, cfg, calibrate=x,
                        select_kernels='fused').summary()
    assert all(v['choice'] == 'fused'
               for v in pinned['lowrank_selection'].values())


def test_export_chain_threads_exit_threshold():
    """export_chain hands the E pass's calibrated operating point to the
    served model, so batch serving exercises ChainState.exit_threshold."""
    fam, params, cfg = _with_exits(RESNET8_CIFAR)
    st = ChainState(family=fam, cfg=cfg, params=params,
                    key=jax.random.key(0), exit_threshold=0.42)
    model = export_chain(st)
    assert model.exit_threshold == 0.42
    x = jax.random.normal(jax.random.key(3), (4, 32, 32, 3))
    pred, stage = model.serve_early_exit(x)     # None -> chain threshold
    assert pred.shape == (4,) and stage.shape == (4,)


# ------------------------------------------------------- batched early exit


def test_early_exit_batch_selection():
    """Earliest confident exit wins; unconfident samples reach the head."""
    logits = jnp.array([[0.0, 5.0], [5.0, 0.0], [0.0, 5.0]])
    exits = {
        0: jnp.array([[9.0, 0.0], [0.1, 0.0], [0.0, 0.1]]),   # conf, no, no
        1: jnp.array([[0.0, 9.0], [9.0, 0.0], [0.1, 0.0]]),   # conf, conf, no
    }
    pred, stage = early_exit_batch(logits, exits, threshold=0.9)
    np.testing.assert_array_equal(np.asarray(stage), [0, 1, -1])
    np.testing.assert_array_equal(np.asarray(pred), [0, 0, 1])


def test_serve_early_exit_runs_batched():
    _, params, cfg = _with_exits(RESNET8_CIFAR)
    model = export_cnn(params, cfg)
    x = jax.random.normal(jax.random.key(3), (16, 32, 32, 3))
    pred, stage = model.serve_early_exit(x, threshold=0.5)
    assert pred.shape == (16,) and stage.shape == (16,)
    assert bool(jnp.all((stage >= -1)
                        & (stage < len(cfg.stage_blocks))))


# ----------------------------------------------------------- quant_conv


@pytest.mark.parametrize('stride,relu', [(1, False), (2, False), (1, True)])
def test_quant_conv_matches_lax_conv_oracle(stride, relu):
    """Pallas quant_conv (interpret) == lax.conv on dequantized operands."""
    k = jax.random.key(0)
    x = jax.random.normal(k, (2, 8, 8, 16))
    w = jax.random.normal(jax.random.fold_in(k, 1), (3, 3, 16, 32)) * 0.1
    b = jax.random.normal(jax.random.fold_in(k, 2), (32,))
    w_q, sw = ops.prequantize_weight(w)
    x_q, sx = ops.quantize_act(x)
    out = quant_conv(x_q, w_q, sx, sw, b, stride=stride, relu=relu,
                     interpret=True)
    expect = ref.quant_conv_ref(x_q, w_q, sx, sw, b, stride=stride,
                                relu=relu)
    assert out.shape == expect.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_quant_conv_1x1_and_no_bias():
    k = jax.random.key(5)
    x = jax.random.normal(k, (2, 8, 8, 8))
    w = jax.random.normal(jax.random.fold_in(k, 1), (1, 1, 8, 16)) * 0.2
    w_q, sw = ops.prequantize_weight(w)
    x_q, sx = ops.quantize_act(x)
    out = quant_conv(x_q, w_q, sx, sw, stride=2, interpret=True)
    expect = ref.quant_conv_ref(x_q, w_q, sx, sw, stride=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_im2col_matches_conv_patches():
    """im2col patch matrix @ flat weights == SAME lax.conv, fp32."""
    k = jax.random.key(7)
    for stride in (1, 2):
        x = jax.random.normal(k, (2, 7, 9, 5))
        w = jax.random.normal(jax.random.fold_in(k, 1), (3, 3, 5, 4))
        patches, (oh, ow) = im2col_nhwc(x, 3, 3, stride)
        got = (patches @ w.reshape(-1, 4)).reshape(2, oh, ow, 4)
        expect = jax.lax.conv_general_dilated(
            x, w, (stride, stride), 'SAME',
            dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------- ops split + shared tiling


def test_prequantize_plus_quant_dense_equals_wrapper():
    k = jax.random.key(0)
    x = jax.random.normal(k, (32, 128))
    w = jax.random.normal(jax.random.fold_in(k, 1), (128, 64)) * 0.1
    w_q, sw = ops.prequantize_weight(w)
    a = ops.quant_dense(x, w_q, sw)
    b = ops.quantize_dense_int8(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    rel = float(jnp.max(jnp.abs(a - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.02, rel


def test_fake_quant_fused_matches_two_pass():
    w = jax.random.normal(jax.random.key(0), (256, 192))
    fused = ops.fake_quant(w, 8, fused=True)
    two = ops.fake_quant(w, 8, fused=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(ref.fake_quant_ref(w, 8)),
                               rtol=1e-5, atol=1e-6)


def test_fake_quant_weight_kernel_path_matches_jnp():
    """The QAT hot-path wiring: kernel-backed fake_quant_weight == the jnp
    grid, and the STE gradient stays identity (no VJP through Pallas)."""
    from repro.core.quantization import fake_quant_weight
    w = jax.random.normal(jax.random.key(0), (128, 96))
    jnp_out = fake_quant_weight(w, 8, use_kernel=False)
    krn_out = fake_quant_weight(w, 8, use_kernel=True)
    np.testing.assert_allclose(np.asarray(krn_out), np.asarray(jnp_out),
                               rtol=1e-5, atol=1e-6)
    g = jax.grad(lambda w: jnp.sum(fake_quant_weight(w, 8,
                                                     use_kernel=True)))(w)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(w), rtol=1e-6)


def test_tiling_fit_block_and_padding():
    assert fit_block(128, 256) == 128
    assert fit_block(128, 96) == 96
    assert fit_block(128, 97) == 97              # dim fits in one block: fine
    with pytest.raises(ValueError, match='pad the dim'):
        fit_block(64, 97)                        # prime: no silent 1-blocks
    assert fit_or_pad(64, 97) == (64, 128)
    assert pad_to(97) == 128 and pad_to(128) == 128


def test_prime_dims_pad_through_kernels():
    """Prime dims LARGER than the block no longer degrade to 1-wide blocks
    — the kernels zero-pad to the next 128 multiple and slice back.  Dims
    like 257/131/139 with 128 blocks force the pad branch (fit_or_pad must
    pad all three: no divisor of a prime > block exceeds the floor)."""
    k = jax.random.key(0)
    M, K, N = 257, 131, 139
    assert fit_or_pad(128, M)[1] > M           # the pad branch is live
    xq = jax.random.randint(k, (M, K), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (K, N), -128, 128,
                            jnp.int8)
    sx = jnp.full((M,), 0.01)
    sw = jnp.full((N,), 0.02)
    b = jax.random.normal(k, (N,))
    from repro.kernels.quant_matmul import quant_matmul
    out = quant_matmul(xq, wq, sx, sw, b, bm=128, bn=128, bk=128,
                       relu=True, interpret=True)
    expect = jnp.maximum(ref.quant_matmul_ref(xq, wq, sx, sw) + b, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    from repro.kernels.fake_quant import fake_quant
    w = jax.random.normal(k, (257, 131))
    np.testing.assert_allclose(
        np.asarray(fake_quant(w, bits=8, bk=128, bn=128, interpret=True)),
        np.asarray(ref.fake_quant_ref(w, 8)), rtol=1e-5, atol=1e-6)
