"""int8 KV cache (kv_cache_bits=8): decode must track the bf16-cache
decode closely, and prefill->decode consistency must hold end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model


@pytest.mark.parametrize('arch', ['tinyllama-1.1b', 'gemma2-9b'])
def test_kv_int8_decode_close_to_fp(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 32
    batch = {'tokens': jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size)}
    _, cache_fp = m.prefill(params, batch, max_len=64)

    cfg8 = cfg.replace(kv_cache_bits=8)
    m8 = build_model(cfg8)
    _, cache_q = m8.prefill(params, batch, max_len=64)
    assert cache_q['blocks'][0]['k'].dtype == jnp.int8

    tok = jnp.full((B,), 7, jnp.int32)
    cur = jnp.asarray(S, jnp.int32)
    lg_fp, _ = m.decode_step(params, tok, cur, cache_fp)
    lg_q, cache_q2 = m8.decode_step(params, tok, cur, cache_q)
    probs_fp = jax.nn.softmax(lg_fp.astype(jnp.float32))
    probs_q = jax.nn.softmax(lg_q.astype(jnp.float32))
    tv = float(0.5 * jnp.abs(probs_fp - probs_q).sum(-1).max())
    assert tv < 0.05, f'int8 cache shifted decode distribution by {tv}'
    # multi-step decode stays finite and consistent in shape
    for t in range(3):
        lg_q, cache_q2 = m8.decode_step(params, tok, cur + 1 + t, cache_q2)
        assert bool(jnp.isfinite(lg_q).all())


def test_kv_int8_halves_cache_bytes():
    cfg = get_smoke_config('qwen2-72b').replace(kv_cache_bits=8)
    m = build_model(cfg)
    cache = jax.eval_shape(lambda: m.init_cache(2, 64))
    c0 = cache['blocks'][0]
    assert c0['k'].dtype == jnp.int8 and 'k_s' in c0
