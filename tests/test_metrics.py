"""ServingMetrics unit tests: percentile edge cases, summary JSON
round-trip, the makespan-skew regression (a run whose earliest arrivals
were all rejected must not report inflated throughput), and the windowed
time-series / telemetry-digest layer the BENCH JSONs record."""
import json

import pytest

from repro.serving import Completion, ServingMetrics, percentile


def _comp(rid, t_arrival, t_done, *, t_start=None, exit_stage=0,
          deadline=None, degraded=False):
    return Completion(rid=rid, logits=None, pred=0, exit_stage=exit_stage,
                      t_arrival=t_arrival, t_done=t_done, t_start=t_start,
                      deadline=deadline, degraded=degraded)


# ------------------------------------------------------------- percentile


def test_percentile_edge_cases():
    assert percentile([], 99) == 0.0
    assert percentile([], 0) == 0.0
    assert percentile([7.0], 0) == 7.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 100) == 7.0
    xs = [4.0, 1.0, 3.0, 2.0]             # unsorted input must not matter
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile(xs, 25) == pytest.approx(1.75)   # linear interp
    assert percentile(range(101), 99) == pytest.approx(99.0)
    assert xs == [4.0, 1.0, 3.0, 2.0], 'input must not be mutated'


# --------------------------------------------------- summary + round-trip


def test_summary_json_roundtrip():
    m = ServingMetrics()
    for i in range(4):
        m.record_completion(_comp(i, 0.001 * i, 0.01 + 0.002 * i,
                                  t_start=0.005, deadline=1.0,
                                  exit_stage=(0 if i < 2 else -1)))
    m.record_batch(0, 4, 8, t=0.0, cost=4e-3)
    m.record_batch(1, 2, 8, t=4e-3, cost=2e-3)
    m.record_rejection(9, 0.02, 'admission', t_arrival=0.015)
    m.record_event('kill', 0.01, replica=0, mid_batch=True)
    m.record_event('scale_up', 0.012, n_replicas=3)
    m.record_gauge('queue_depth', 0.0, 5)
    s = m.summary()
    s['timeseries'] = m.timeseries(n_windows=4)
    got = json.loads(json.dumps(s))        # everything JSON-serializable
    assert got == s
    assert got['n_requests'] == 4
    assert got['availability'] == pytest.approx(4 / 5)
    assert got['slo']['n_with_deadline'] == 5   # deadline + rejection
    assert got['resilience']['kills'] == 1
    assert got['resilience']['peak_replicas'] == 3
    assert got['timeseries']['n_windows'] == 4


def test_makespan_counts_rejected_arrivals():
    """Regression: the earliest request being REJECTED must still anchor
    the makespan — otherwise throughput is computed over the shorter
    completion-only window and reads too high."""
    skew = ServingMetrics()
    skew.record_rejection(0, t=0.0, reason='admission', t_arrival=0.0)
    skew.record_completion(_comp(1, 1.0, 2.0))
    assert skew.t_first_offered == 0.0
    assert skew.summary()['throughput_rps'] == pytest.approx(1 / 2.0)
    # without the arrival the old skew reappears (documented fallback:
    # the rejection *decision* time still counts as offered)
    legacy = ServingMetrics()
    legacy.record_rejection(0, t=0.5, reason='admission')
    legacy.record_completion(_comp(1, 1.0, 2.0))
    assert legacy.summary()['throughput_rps'] == pytest.approx(1 / 1.5,
                                                               abs=1e-3)
    # all-completions runs are unchanged by the fix
    plain = ServingMetrics()
    plain.record_completion(_comp(0, 1.0, 2.0))
    assert plain.summary()['throughput_rps'] == pytest.approx(1.0)


# ------------------------------------------------------------ time series


def test_timeseries_windows_and_gauges():
    m = ServingMetrics()
    assert m.timeseries() == {}            # no samples -> no block
    # 2 completions early, 2 late; batches split 75/25 between stages
    for rid, (t, lat) in enumerate([(0.1, 0.01), (0.2, 0.02),
                                    (3.8, 0.04), (3.9, 0.08)]):
        m.record_completion(_comp(rid, 0.0, t))
        m.latencies[-1] = lat               # decouple latency from t_done
        m.lat_samples[-1] = (t, lat)
    m.record_batch(0, 8, 8, t=0.0, cost=3e-3)
    m.record_batch(0, 6, 8, t=0.1, cost=3e-3)
    m.record_batch(1, 4, 8, t=3.5, cost=2e-3)
    m.record_gauge('queue_depth', 0.0, 2)
    m.record_gauge('queue_depth', 1.0, 7)
    ts = m.timeseries(n_windows=4)
    assert ts['n_windows'] == 4
    assert ts['window_s'] == pytest.approx(3.9 / 4)
    assert ts['completions'] == [2, 0, 0, 2]
    assert ts['rolling_p99_s'][1] is None, 'empty window is None, not 0'
    assert ts['rolling_p99_s'][3] == pytest.approx(
        percentile([0.04, 0.08], 99), abs=1e-6)
    assert ts['occupancy'][0] == pytest.approx((1.0 + 0.75) / 2)
    assert ts['occupancy'][3] == pytest.approx(0.5)
    share = ts['stage_exec_share']
    assert share['0'] == pytest.approx(6e-3 / 8e-3)
    assert share['1'] == pytest.approx(2e-3 / 8e-3)
    q = ts['queue_depth']
    assert q['overall_peak'] == 7.0
    assert q['peak'][0] == 2.0
    assert q['peak'][1] == 7.0
    assert q['peak'][3] == 7.0, 'gauges carry the last value forward'
    worst = ts['worst_p99_window']
    assert worst['p99_s'] == ts['rolling_p99_s'][3]
    assert worst['t_start'] == pytest.approx(3 * 3.9 / 4)


def test_timeseries_degenerate_span():
    m = ServingMetrics()
    m.record_completion(_comp(0, 0.0, 0.0))   # t0 == t1: no window span
    assert m.timeseries() == {}
    assert m.telemetry_digest() == 'telemetry: no timestamped samples'


def test_telemetry_digest_mentions_all_parts():
    m = ServingMetrics()
    m.record_completion(_comp(0, 0.0, 1.0))
    m.record_batch(0, 8, 8, t=0.0, cost=3e-3)
    m.record_gauge('queue_depth', 0.1, 4)
    m.record_event('scale_up', 0.5, n_replicas=3)
    d = m.telemetry_digest()
    assert d.startswith('telemetry: ')
    assert 'peak queue depth 4' in d
    assert 'worst p99' in d
    assert 's0=100%' in d
    assert 'peak replicas 3' in d
