"""Resilience-layer tests: SLO admission/degradation (never silently
late), the replica pool under chaos (kill mid-batch, straggler slowdown,
elastic scaling) with every completion bit-exact vs an undisturbed run,
checkpoint-backed failover through the registry, and the property sweep
pinning scheduler-side exit decisions to core/export.early_exit_batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn import RESNET8_CIFAR
from repro.core.export import (calibrate_exit_threshold, early_exit_batch,
                               export_cnn)
from repro.core.family import CNNFamily
from repro.data import SyntheticImages
from repro.serving import (ChaosPlan, ContinuousBatchScheduler,
                           ModelRegistry, ReplicaPoolScheduler, Request,
                           RequestQueue, SLOPolicy, exit_decisions)

SLOTS = 8
COSTS = [4e-3, 2e-3, 1e-3]                # simulated per-segment batch costs


@pytest.fixture(scope='module')
def family():
    return CNNFamily(SyntheticImages())


@pytest.fixture(scope='module')
def exported(family):
    base = RESNET8_CIFAR
    params = family.init(jax.random.key(0), base)
    params, cfg = family.add_exits(jax.random.key(2), params, base,
                                   family.default_exit_points(base))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    model = export_cnn(params, cfg, calibrate=calib)
    return model, calibrate_exit_threshold(model, calib)


def _trace(n, rate=2000.0, seed=0, deadlines=None):
    xs = jax.random.normal(jax.random.key(11), (max(n, 1), 32, 32, 3))
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(i, xs[i], float(t[i]),
                    deadline=(None if deadlines is None
                              else float(t[i] + deadlines[i])))
            for i in range(n)]


# ------------------------------------------------------------- SLO policy


def test_slo_policy_decisions():
    slo = SLOPolicy()
    assert slo.admit(deadline=0.0, now=0.0, backlog=99,
                     slots=8)                          # no costs: admit all
    slo.seed(COSTS)
    assert slo.max_cost == 4e-3
    # empty backlog: need one seg-0 batch + one head-of-line blocking exec
    assert slo.admit(now=0.0, deadline=8e-3, backlog=0, slots=8)
    assert not slo.admit(now=0.0, deadline=7.9e-3, backlog=0, slots=8)
    # 8 queued ahead at 8 slots -> two seg-0 batches before service
    assert not slo.admit(now=0.0, deadline=10e-3, backlog=8, slots=8)
    assert slo.admit(now=0.0, deadline=12e-3, backlog=8, slots=8)
    assert slo.latest_start(1, deadline=10e-3) == pytest.approx(8e-3)
    # in the charged batch: answers at now + charge
    assert slo.affordable(5e-3, now=1e-3, k=1, charge=4e-3, in_batch=True)
    # not in it: must fit its own segment after the charge
    assert not slo.affordable(5e-3, now=1e-3, k=1, charge=4e-3,
                              in_batch=False)
    slo2 = SLOPolicy(slack=2.0)
    slo2.seed(COSTS)
    assert slo2._cost(0) == pytest.approx(8e-3)   # slack scales estimates
    slo3 = SLOPolicy(stage_costs=[None, None])
    slo3.observe(0, 4e-3)
    assert slo3._cost(0) == pytest.approx(4e-3)   # learned online
    slo3.observe(0, 8e-3)
    assert 4e-3 < slo3._cost(0) < 8e-3            # EWMA blend


def test_request_queue_requeue_fifo():
    q = RequestQueue([Request(i, None, float(i)) for i in range(4)])
    got = q.pop_ready(10.0, 2)
    assert [r.rid for r in got] == [0, 1]
    # failover replay: rid 1 re-enters AT its original arrival position,
    # ahead of later arrivals still queued
    q.requeue(got[1])
    assert [r.rid for r in q.pop_ready(10.0, 3)] == [1, 2, 3]
    # a fresh push must stay in arrival order; replay must use requeue()
    q.push(Request(9, None, 9.0))
    with pytest.raises(ValueError, match='requeue'):
        q.push(Request(10, None, 1.0))
    q.requeue(Request(10, None, 1.0))
    assert [r.rid for r in q.pop_ready(10.0, 2)] == [10, 9]


# ------------------------------------------ SLO on the single scheduler


def test_slo_rejects_hopeless_admission(exported):
    model, thr = exported
    # budget below one seg-0 batch + head-of-line blocking: unservable
    reqs = _trace(SLOTS, deadlines=[1e-3] * SLOTS)
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        slo=SLOPolicy()).run_trace(reqs)
    assert comp == {}
    s = met.summary()
    assert s['n_rejected'] == SLOTS
    assert s['availability'] == 0.0
    assert s['slo'] == {'n_with_deadline': SLOTS, 'n_on_time': 0,
                        'n_late': 0, 'attainment': 0.0}
    assert all(reason == 'admission' for _, _, reason in met.rejections)


def test_slo_degrades_to_exit_head_never_late(exported):
    model, _ = exported
    # threshold 2.0: nobody exits voluntarily — every completion wants
    # full depth.  A near-simultaneous burst of 3 full batches with one
    # shared budget creates contention: the first batch affords full
    # depth, a later batch's budget runs out mid-service (degraded at an
    # exit head, on time), and the tail can't even cover admission
    # (rejected).  Nobody is ever late.
    n = 3 * SLOTS
    budget = 2 * COSTS[0] + COSTS[1] + COSTS[2] + 2e-3
    reqs = _trace(n, rate=50000.0, deadlines=[budget] * n)
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=2.0, stage_costs=COSTS,
        slo=SLOPolicy()).run_trace(reqs)
    s = met.summary()
    assert len(comp) + s['n_rejected'] == n, 'requests lost'
    assert s['n_degraded'] >= 1, 'contention must force a degrade'
    assert s['n_rejected'] >= 1, 'the tail must be rejected at admission'
    assert s['slo']['n_late'] == 0
    assert sum(s['degraded_exit_mix'].values()) == s['n_degraded']
    for r in reqs:
        if r.rid not in comp:
            continue
        c = comp[r.rid]
        assert c.on_time, f'request {r.rid} completed late'
        if not c.degraded:
            continue
        assert c.exit_stage >= 0
        # degraded logits are the head's own row — bit-exact, only the
        # exit DECISION was forced
        xb = jnp.concatenate([r.x[None], jnp.zeros((SLOTS - 1,) + r.x.shape,
                                                   r.x.dtype)])
        _, exits = model.fn_exits(model.params, xb)
        np.testing.assert_array_equal(
            c.logits, np.asarray(exits[c.exit_stage], np.float32)[0])


def test_slo_never_late_random_budgets(exported):
    """The acceptance bar: with deadlines enabled, NO admitted request
    completes past its deadline on the simulated clock — every deadline
    request is on time (possibly degraded) or rejected at admission."""
    model, thr = exported
    rng = np.random.default_rng(42)
    n = 4 * SLOTS
    budgets = rng.uniform(0.3, 3.0, size=n) * sum(COSTS)
    reqs = _trace(n, rate=1500.0, deadlines=budgets)
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        slo=SLOPolicy()).run_trace(reqs)
    s = met.summary()
    assert len(comp) + s['n_rejected'] == n, 'requests lost'
    assert s['slo']['n_late'] == 0
    assert s['slo']['n_on_time'] == len(comp)
    assert all(c.on_time for c in comp.values())


# ------------------------------------------------------- replica pool


def test_pool_requires_stage_costs(exported):
    model, thr = exported
    with pytest.raises(ValueError, match='stage_costs'):
        ReplicaPoolScheduler(model, slots=SLOTS, threshold=thr)


def test_pool_matches_single_executor_bit_exact(exported):
    model, thr = exported
    reqs = _trace(3 * SLOTS + 3)
    single, _ = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr,
        stage_costs=COSTS).run_trace(reqs)
    pooled, met = ReplicaPoolScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        replicas=3, min_replicas=3).run_trace(reqs)
    assert len(pooled) == len(reqs)
    for r in reqs:
        assert pooled[r.rid].exit_stage == single[r.rid].exit_stage
        np.testing.assert_array_equal(pooled[r.rid].logits,
                                      single[r.rid].logits)


def test_pool_chaos_kill_requeues_and_restores(exported, family, tmp_path):
    """A replica killed mid-batch loses nothing: its in-flight requests
    requeue, a replacement restores from the chain checkpoint through the
    registry, and every completion stays bit-exact vs the undisturbed
    pool."""
    from repro.checkpoint import save_chain_state
    from repro.core.passes import ChainState

    model, thr = exported
    # persist the ORIGINAL float params the export was built from
    base = RESNET8_CIFAR
    params = family.init(jax.random.key(0), base)
    params, cfg = family.add_exits(jax.random.key(2), params, base,
                                   family.default_exit_points(base))
    st = ChainState(family=family, cfg=cfg.replace(w_bits=8, a_bits=8),
                    params=params, key=jax.random.key(7),
                    exit_threshold=thr)
    save_chain_state(str(tmp_path), st, step=0)
    reg = ModelRegistry()
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    served = reg.load('m', str(tmp_path), family, calibrate=calib)
    restores = []

    def restore():
        restores.append(1)
        return reg.restore('m')

    reqs = _trace(3 * SLOTS, rate=4000.0)
    kw = dict(slots=SLOTS, threshold=thr, stage_costs=COSTS, replicas=2,
              min_replicas=2)
    undisturbed, _ = ReplicaPoolScheduler(served, **kw).run_trace(reqs)
    # first seg-0 batch dispatches once 8 requests arrived (~2ms at
    # rate 4000) and flies for COSTS[0]=4ms: t=4ms is mid-batch
    plan = ChaosPlan(kills=((4e-3, 0),))
    comp, met = ReplicaPoolScheduler(
        served, chaos=plan, restore=restore,
        restore_delay=COSTS[0], **kw).run_trace(reqs)
    assert len(comp) == len(reqs), 'kill lost requests'
    kills = [(k, i) for k, _, i in met.events if k == 'kill']
    assert kills and kills[0][1]['mid_batch'], 'kill must land mid-batch'
    assert met.summary()['resilience']['failovers'] == 1
    assert restores == [1], 'failover must restore through the registry'
    for r in reqs:
        assert comp[r.rid].exit_stage == undisturbed[r.rid].exit_stage
        np.testing.assert_array_equal(comp[r.rid].logits,
                                      undisturbed[r.rid].logits)


def test_pool_straggler_flagged_and_evicted(exported):
    model, thr = exported
    reqs = _trace(6 * SLOTS, rate=50000.0)     # near-simultaneous arrivals
    # pin the pool to exactly 2 replicas: elastic scale-up would dilute the
    # slowed replica's share of batches and starve the consecutive-flag
    # eviction counter
    kw = dict(slots=SLOTS, threshold=thr, stage_costs=COSTS, replicas=2,
              min_replicas=2, max_replicas=2)
    undisturbed, _ = ReplicaPoolScheduler(model, **kw).run_trace(reqs)
    plan = ChaosPlan(slowdowns=((0.0, 0, 2.5),))
    comp, met = ReplicaPoolScheduler(
        model, chaos=plan, evict_after=2, **kw).run_trace(reqs)
    assert len(comp) == len(reqs)
    res = met.summary()['resilience']
    assert res['straggler_flags'] >= 1, 'slowdown never flagged'
    assert res['evictions'] >= 1, 'persistent straggler never evicted'
    flagged = {i['replica'] for k, _, i in met.events
               if k == 'straggler_flag'}
    assert flagged == {0}, 'only the slowed replica may be flagged'
    for r in reqs:
        assert comp[r.rid].exit_stage == undisturbed[r.rid].exit_stage
        np.testing.assert_array_equal(comp[r.rid].logits,
                                      undisturbed[r.rid].logits)


def test_pool_elastic_scaling(exported):
    model, thr = exported
    reqs = _trace(4 * SLOTS, rate=50000.0)     # a burst: deep backlog
    elastic, e_met = ReplicaPoolScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        replicas=1, max_replicas=4).run_trace(reqs)
    fixed, f_met = ReplicaPoolScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        replicas=1, max_replicas=1).run_trace(reqs)
    assert len(elastic) == len(fixed) == len(reqs)
    res = e_met.summary()['resilience']
    assert res['scale_ups'] >= 1
    assert res['peak_replicas'] >= 2
    assert f_met.summary()['resilience']['peak_replicas'] == 1
    # scaling from queue depth must actually shorten the makespan
    assert max(c.t_done for c in elastic.values()) < \
        max(c.t_done for c in fixed.values())


def test_pool_slo_never_late_under_chaos(exported):
    model, thr = exported
    rng = np.random.default_rng(7)
    n = 4 * SLOTS
    budgets = rng.uniform(0.4, 4.0, size=n) * sum(COSTS)
    reqs = _trace(n, rate=4000.0, deadlines=budgets)
    plan = ChaosPlan(kills=((5e-3, None),), slowdowns=((0.0, 1, 2.0),))
    comp, met = ReplicaPoolScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS, replicas=2,
        min_replicas=2, slo=SLOPolicy(), chaos=plan).run_trace(reqs)
    s = met.summary()
    assert len(comp) + s['n_rejected'] == n, 'requests lost under chaos'
    assert s['slo']['n_late'] == 0
    assert all(c.on_time for c in comp.values())


# --------------------------------------------- decision-rule equivalence


def test_exit_decisions_matches_early_exit_batch_property():
    """Seeded random sweep: the scheduler-side exit_decisions and the
    export-side early_exit_batch must pick the identical (exit stage,
    answering head) on arbitrary logits — one decision rule, no drift.
    Includes thresholds equal to an exact confidence value (strict >)."""
    rng = np.random.default_rng(1234)
    for trial in range(50):
        b = int(rng.integers(1, 17))
        c = int(rng.integers(2, 11))
        stages = sorted(rng.choice(8, size=int(rng.integers(1, 4)),
                                   replace=False).tolist())
        logits = jnp.asarray(rng.normal(size=(b, c)) * rng.uniform(0.5, 4))
        exits = {int(s): jnp.asarray(rng.normal(size=(b, c))
                                     * rng.uniform(0.5, 4))
                 for s in stages}
        if trial % 5 == 0:
            # threshold exactly AT a head's confidence: strictly-greater
            # means that sample must NOT exit there, in both rules
            from repro.core.export import exit_confidence
            s0 = stages[0]
            threshold = float(np.asarray(
                exit_confidence(exits[s0]))[int(rng.integers(b))])
        else:
            threshold = float(rng.uniform(0.1, 1.0))
        stage_sched, ans = exit_decisions(logits, exits, threshold)
        pred_core, stage_core = early_exit_batch(logits, exits, threshold)
        np.testing.assert_array_equal(stage_sched,
                                      np.asarray(stage_core, np.int64))
        np.testing.assert_array_equal(ans.argmax(-1),
                                      np.asarray(pred_core))
