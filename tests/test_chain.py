"""Compression-chain system tests: passes transform state coherently,
BitOps accounting is monotone, planner reproduces the paper's sequence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn import RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR
from repro.core import bitops as bo
from repro.core.chain import OPTIMAL_SEQUENCE, run_chain
from repro.core.family import CNNFamily, LMFamily
from repro.core.passes import PASSES, Trainer, init_chain_state
from repro.core.planner import OrderPlanner, pareto_frontier, theoretical_order
from repro.data import SyntheticImages, SyntheticTokens

FAST = Trainer(batch=32, steps=8, lr=2e-3, eval_n=1, eval_batch=64)


@pytest.fixture(scope='module')
def cnn_family():
    return CNNFamily(SyntheticImages(difficulty=0.6), image=32)


@pytest.fixture(scope='module')
def base_state(cnn_family):
    return init_chain_state(cnn_family, RESNET8_CIFAR, jax.random.key(0),
                            FAST)


def test_theoretical_order_is_dpqe():
    assert theoretical_order('DPQE') == 'DPQE'
    assert OPTIMAL_SEQUENCE == 'DPQE'
    # the default plans the full registry: the built-in five passes give
    # the N-pass law D->P->L->Q->E (L ties Q on (static, sub-neuron) and
    # orders before it deterministically)
    assert theoretical_order() == 'DPLQE'
    assert theoretical_order('EQLPD') == 'DPLQE'   # input order irrelevant


def test_planner_topological_sort_unique():
    pl = OrderPlanner('DPQE')
    # the paper's six pairwise outcomes
    for a, b in [('D', 'P'), ('D', 'Q'), ('D', 'E'), ('P', 'Q'),
                 ('P', 'E'), ('Q', 'E')]:
        pl.add_pairwise(a, b, 'AB')
    assert pl.topological_order() == 'DPQE'


def test_planner_detects_cycle():
    pl = OrderPlanner('DPQ')
    pl.add_pairwise('D', 'P', 'AB')
    pl.add_pairwise('P', 'Q', 'AB')
    pl.add_pairwise('D', 'Q', 'BA')         # Q before D: cycle
    with pytest.raises(ValueError):
        pl.topological_order()


def test_pareto_frontier():
    pts = [(0.9, 10), (0.8, 100), (0.85, 50), (0.7, 50), (0.95, 5)]
    front = pareto_frontier(pts)
    assert (0.7, 50) not in front           # dominated by (0.85, 50)
    assert (0.8, 100) in front and (0.95, 5) in front


def test_full_chain_dpqe(cnn_family, base_state):
    st = run_chain(cnn_family, None, 'DPQE',
                   {'D': {'factor': 0.5}, 'P': {'ratio': 0.3},
                    'Q': {'w_bits': 4, 'a_bits': 8},
                    'E': {'threshold': 0.8}},
                   FAST, state=base_state)
    labels = [h['pass'] for h in st.history]
    assert labels == ['baseline', 'D', 'P', 'Q', 'E']
    crs = [h['BitOpsCR'] for h in st.history]
    assert crs[0] == 1.0
    # monotone up to the exit-head overhead (E adds head MACs; with low
    # exit rates at toy scale the expected cost can tick up ~2%)
    assert all(b >= a * 0.97 for a, b in zip(crs, crs[1:])), \
        f'BitOpsCR must be ~monotone along the chain: {crs}'
    assert crs[-1] > 10, 'D+P+Q should compress BitOps >10x even at toy scale'
    assert st.cfg.w_bits == 4 and st.cfg.a_bits == 8
    assert st.exit_probs is not None


@pytest.mark.parametrize('cfg', [VGG8_CIFAR, MOBILENET_SMALL_CIFAR])
def test_prune_physically_shrinks(cnn_family, cfg):
    params = cnn_family.init(jax.random.key(1), cfg)
    n0 = sum(x.size for x in jax.tree_util.tree_leaves(params))
    pruned, cfg2 = cnn_family.prune(params, cfg, 0.5)
    n1 = sum(x.size for x in jax.tree_util.tree_leaves(pruned))
    assert n1 < n0 * 0.85
    # pruned model still runs
    x = jax.random.normal(jax.random.key(2), (2, 32, 32, 3))
    lg = cnn_family.logits(pruned, cfg2, x)
    assert lg.shape == (2, 10) and bool(jnp.isfinite(lg).all())


def test_quant_pass_sets_bits_and_keeps_finite(cnn_family, base_state):
    st = PASSES['Q'].apply(base_state, {'w_bits': 2, 'a_bits': 4}, FAST)
    assert st.cfg.w_bits == 2 and st.cfg.a_bits == 4
    x = jax.random.normal(jax.random.key(3), (2, 32, 32, 3))
    assert bool(jnp.isfinite(cnn_family.logits(st.params, st.cfg, x)).all())


def test_exit_pass_produces_probs(cnn_family, base_state):
    st = PASSES['E'].apply(base_state, {'threshold': 0.5}, FAST)
    assert st.exit_probs and all(0 <= p <= 1 for p in st.exit_probs.values())
    assert st.dyn_accuracy is not None


# ----------------------------------------------------------- LM-side chain


def test_lm_chain_passes():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config('tinyllama-1.1b', layers=4).replace(
        vocab_size=128)
    fam = LMFamily(SyntheticTokens(vocab=cfg.vocab_size), seq=32)
    tr = Trainer(batch=8, steps=6, lr=2e-3, eval_n=1, eval_batch=16)
    st = init_chain_state(fam, cfg, jax.random.key(0), tr)
    st = run_chain(fam, None, 'PQ',
                   {'P': {'ratio': 0.25}, 'Q': {'w_bits': 8, 'a_bits': 8}},
                   tr, state=st)
    assert st.cfg.d_ff < cfg.d_ff                  # physically pruned
    assert st.cfg.w_bits == 8
    assert st.history[-1]['BitOpsCR'] > 1.0


def test_lm_expert_pruning():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config('mixtral-8x7b').replace(vocab_size=128)
    fam = LMFamily(SyntheticTokens(vocab=128), seq=16)
    params = fam.init(jax.random.key(0), cfg)
    pruned, cfg2 = fam.prune(params, cfg, 0.5)
    assert cfg2.n_experts == 2
    batch = fam.train_batch(jax.random.key(1), 2)
    lg = fam.logits_of(pruned, cfg2, batch)
    assert bool(jnp.isfinite(lg).all())


# ------------------------------------------------------------------ bitops


def test_bitops_quant_scaling():
    cfg = RESNET8_CIFAR
    full = bo.cnn_bitops(cfg)
    q8 = bo.cnn_bitops(cfg.replace(w_bits=8, a_bits=8))
    assert abs(full / q8 - (32 * 32) / (8 * 8)) < 1e-6


def test_bitops_early_exit_reduces_cost():
    cfg = RESNET8_CIFAR.replace(exit_stages=(0, 1))
    full = bo.cnn_bitops(cfg)
    dyn = bo.cnn_bitops(cfg, exit_probs={0: 0.5, 1: 0.5})
    assert dyn < full


def test_lm_bitops_moe_counts_active_only():
    from repro.configs import get_config
    cfg = get_config('mixtral-8x7b')
    moe = bo.lm_bitops(cfg, 128)
    dense_equiv = bo.lm_bitops(cfg.replace(n_experts=0, top_k=0,
                                           d_ff=cfg.moe_d_ff), 128)
    # top-2 of 8 experts ~ 2x a dense MLP of the same expert size, not 8x
    assert moe < dense_equiv * 2.6
