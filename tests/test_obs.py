"""Observability-layer tests: the tracer's Chrome-trace export must
round-trip losslessly (a written trace is a checkable artifact, not a
picture), check_trace must be green on real scheduler/pool runs and red
on each seeded corruption, the analysis registry's trace-invariants rule
must fire on its mutant, and the export's measure-mode kernel timing must
surface both kernel.launch spans and the measured-vs-modeled
lowering_cost_delta block."""
import json

import jax
import numpy as np
import pytest

from repro.configs.cnn import RESNET8_CIFAR
from repro.core.export import calibrate_exit_threshold, export_cnn
from repro.core.family import CNNFamily
from repro.data import SyntheticImages
from repro.obs import (NULL_TRACER, NullTracer, Span, TraceInvariantError,
                       Tracer, as_tracer, check_trace, load_chrome_trace,
                       spans_to_chrome)
from repro.serving import (ChaosPlan, ContinuousBatchScheduler,
                           ReplicaPoolScheduler, Request)

SLOTS = 8
COSTS = [4e-3, 2e-3, 1e-3]


@pytest.fixture(scope='module')
def exported():
    fam = CNNFamily(SyntheticImages())
    base = RESNET8_CIFAR
    params = fam.init(jax.random.key(0), base)
    params, cfg = fam.add_exits(jax.random.key(2), params, base,
                                fam.default_exit_points(base))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    model = export_cnn(params, cfg, calibrate=calib)
    return model, calibrate_exit_threshold(model, calib)


def _trace(n, rate=2000.0, seed=0):
    xs = jax.random.normal(jax.random.key(11), (max(n, 1), 32, 32, 3))
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(i, xs[i], float(t[i])) for i in range(n)]


# ------------------------------------------------------------ tracer core


def test_null_tracer_is_allocation_free_default():
    assert as_tracer(None) is NULL_TRACER
    assert not NULL_TRACER.enabled
    NULL_TRACER.add('x', 0, 1, track='t')
    NULL_TRACER.async_span('x', 0, 1, track='t', cid=0)
    NULL_TRACER.instant('x', 0, track='t')
    NULL_TRACER.counter('x', 0, 1.0)
    with NULL_TRACER.span('x', track='t'):
        pass
    assert NULL_TRACER.spans == []
    t = Tracer()
    assert as_tracer(t) is t and t.enabled
    assert isinstance(NULL_TRACER, NullTracer)


def test_tracer_span_contextmanager_uses_wall_clock():
    t = Tracer()
    with t.span('export.calibrate', track='export', config='c'):
        pass
    (s,) = t.spans
    assert s.name == 'export.calibrate' and s.args == {'config': 'c'}
    assert 0.0 <= s.t0 <= s.t1
    assert s.dur == s.t1 - s.t0


def test_chrome_roundtrip_all_kinds(tmp_path):
    t = Tracer()
    t.add('stage.exec', 0.001, 0.005, track='replica0',
          stage=0, live=8, slots=8, rids=[0, 1])
    t.add('failover.restore', 0.005, 0.009, track='replica10',
          replaced=0)
    t.async_span('request.queue', 0.000, 0.001, track='cohort0', cid=1,
                 requeued=False)
    t.instant('compaction', 0.005, track='replica0', stage=0, n_exit=4,
              n_survive=4)
    t.counter('queue_depth', 0.002, 3.0)
    path = str(tmp_path / 'trace.json')
    t.write(path)
    got = load_chrome_trace(path)
    assert sorted(s.name for s in got) == sorted(s.name for s in t.spans)
    by_name = {s.name: s for s in got}
    for orig in t.spans:
        g = by_name[orig.name]
        assert g.kind == orig.kind and g.track == orig.track
        assert g.t0 == pytest.approx(orig.t0, abs=1e-9)
        assert g.t1 == pytest.approx(orig.t1, abs=1e-9)
    assert by_name['request.queue'].cid == 1
    assert by_name['stage.exec'].args['rids'] == [0, 1]
    assert by_name['queue_depth'].args == {'value': 3.0}
    # process/thread structure: serving tracks in pid 1 in natural order
    # (replica10 after replica0), cohort in pid 2
    doc = json.load(open(path))
    names = {(e['pid'], e['tid']): e['args']['name']
             for e in doc['traceEvents']
             if e.get('ph') == 'M' and e['name'] == 'thread_name'}
    assert names[(1, 1)] == 'replica0' and names[(1, 2)] == 'replica10'
    assert any(pid == 2 for pid, _ in names)
    procs = {e['pid']: e['args']['name'] for e in doc['traceEvents']
             if e.get('ph') == 'M' and e['name'] == 'process_name'}
    assert procs[1] == 'serving' and procs[2] == 'requests'


def test_load_chrome_trace_rejects_torn_async():
    doc = spans_to_chrome([Span('request.queue', 0.0, 1.0, 'cohort0',
                                kind='async', cid=5)])
    doc['traceEvents'] = [e for e in doc['traceEvents']
                          if e.get('ph') != 'e']
    with pytest.raises(ValueError, match='torn async'):
        load_chrome_trace(doc)


# ----------------------------------------------------------- check_trace


def test_check_trace_clean_and_each_corruption():
    clean = [
        Span('stage.exec', 0.000, 0.004, 'replica0',
             args={'stage': 0, 'live': 8, 'slots': 8, 'rids': [0]}),
        Span('stage.exec', 0.004, 0.006, 'replica0',
             args={'stage': 1, 'live': 4, 'slots': 8, 'rids': [0]}),
    ]
    assert check_trace(clean) == []
    torn = [Span('stage.exec', 0.010, 0.008, 'replica1',
                 args={'stage': 0})]
    assert any('torn' in m for m in check_trace(torn))
    overlap = clean + [Span('stage.exec', 0.002, 0.005, 'replica0',
                            args={'stage': 0, 'rids': [9]})]
    assert any('concurrent' in m or 'overlaps' in m
               for m in check_trace(overlap))
    missing = [Span('stage.exec', 0.0, 0.001, 'replica0')]
    assert any('missing "stage"' in m for m in check_trace(missing))
    with pytest.raises(TraceInvariantError) as ei:
        check_trace(torn, strict=True)
    assert ei.value.violations


def test_check_trace_completion_extents(exported):
    """With completions, the span tree must cover each latency exactly —
    and a shifted exec span is caught."""
    model, thr = exported
    reqs = _trace(2 * SLOTS)
    tracer = Tracer()
    comp, _ = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        tracer=tracer).run_trace(reqs)
    assert check_trace(tracer, comp) == []
    # corrupt: stretch the last stage.exec past the completion time
    spans = list(tracer.spans)
    i = max(range(len(spans)), key=lambda j: spans[j].t1
            if spans[j].name == 'stage.exec' else -1.0)
    s = spans[i]
    spans[i] = Span(s.name, s.t0, s.t1 + 1.0, s.track, s.kind, s.cid,
                    s.args)
    assert any('extent mismatch' in m for m in check_trace(spans, comp))


# ------------------------------------------------- scheduler integration


def test_continuous_scheduler_trace_is_valid(exported):
    model, thr = exported
    reqs = _trace(3 * SLOTS + 5)
    tracer = Tracer()
    comp, _ = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS,
        tracer=tracer).run_trace(reqs)
    assert len(comp) == len(reqs)
    assert check_trace(tracer, comp, strict=True) == []
    queue = [s for s in tracer.spans if s.name == 'request.queue']
    assert sorted(s.cid for s in queue) == sorted(r.rid for r in reqs)
    execs = [s for s in tracer.spans if s.name == 'stage.exec']
    assert execs and all(s.track == 'executor0' for s in execs)
    assert any(s.name == 'compaction' for s in tracer.spans)


def test_pool_chaos_trace_shows_kill_and_failover(exported):
    """The chaos story must be legible in the trace: a killed stage.exec
    truncated at the kill on the victim's track, the requeued request's
    second queue span starting AT the kill (no double-counted wait), and
    failover.restore on the replacement's track — all while the full
    invariant check stays green."""
    model, thr = exported
    reqs = _trace(3 * SLOTS, rate=4000.0)
    tracer = Tracer()
    plan = ChaosPlan(kills=((4e-3, 0),))
    comp, met = ReplicaPoolScheduler(
        model, slots=SLOTS, threshold=thr, stage_costs=COSTS, replicas=2,
        min_replicas=2, chaos=plan, restore=lambda: model,
        restore_delay=COSTS[0], tracer=tracer).run_trace(reqs)
    assert len(comp) == len(reqs)
    assert check_trace(tracer, comp, strict=True) == []
    killed = [s for s in tracer.spans
              if s.name == 'stage.exec' and s.args.get('killed')]
    assert killed, 'kill left no killed stage.exec span'
    (kt,) = {s.track for s in killed}
    restores = [s for s in tracer.spans if s.name == 'failover.restore']
    assert restores and restores[0].track != kt, \
        'restore must land on the replacement replica, not the victim'
    assert restores[0].dur == pytest.approx(COSTS[0])
    t_kill = killed[0].t1
    requeued = [s for s in tracer.spans if s.name == 'request.queue'
                and s.args.get('requeued')]
    assert requeued, 'killed flight must requeue its requests'
    assert all(s.t0 == pytest.approx(t_kill) for s in requeued)
    # rids on the killed flight get exactly two queue spans
    rid = int(killed[0].args['rids'][0])
    qs = [s for s in tracer.spans
          if s.name == 'request.queue' and s.cid == rid]
    assert len(qs) == 2


# ------------------------------------------------------- analysis + gate


def test_analysis_trace_rule_green_and_red():
    from repro import analysis
    from repro.analysis.mutations import MUTANTS
    clean = [Span('stage.exec', 0.0, 0.004, 'replica0',
                  args={'stage': 0, 'live': 8, 'slots': 8, 'rids': [0]})]
    rep = analysis.check(trace=clean, rules=('trace-invariants',))
    assert rep.ok, rep.render()
    assert rep.target == 'trace'
    mut = analysis.check(**MUTANTS['trace-invariants']())
    assert not mut.ok
    errs = [f for f in mut.findings if f.severity == 'error']
    assert len(errs) >= 2, 'both seeded corruptions must be flagged'


# ------------------------------------------------ export kernel profiling


def test_export_measure_mode_emits_kernel_spans():
    fam = CNNFamily(SyntheticImages())
    base = RESNET8_CIFAR
    params = fam.init(jax.random.key(0), base)
    params, _, _ = fam.factorize(params, base, energy=0.6, min_rank=2)
    cfg = base.replace(w_bits=8, a_bits=8)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    tracer = Tracer()
    model = export_cnn(params, cfg, use_pallas=True, calibrate=x,
                      select_kernels='measure', tracer=tracer)
    cal = [s for s in tracer.spans if s.name == 'export.calibrate']
    assert len(cal) == 1 and cal[0].track == 'export'
    assert cal[0].args['select_kernels'] == 'measure'
    launches = [s for s in tracer.spans if s.name == 'kernel.launch']
    assert launches, 'measure mode must time kernels through the tracer'
    assert {s.args['variant'] for s in launches} == {'fused', 'chained'}
    assert all(s.track == 'export' and s.dur >= 0 for s in launches)
    assert check_trace(tracer) == []
    # the measured-vs-modeled delta block rides on the plan summary
    delta = model.plan.summary()['lowering_cost_delta']
    assert delta, 'measure mode must report measured-vs-modeled deltas'
    for d in delta.values():
        assert d['measured_fused_us'] > 0
        # ratios come from the unrounded timings (the us fields are
        # rounded to 0.1us for the JSON), so check sign/consistency only
        assert d['fused_measured_over_modeled'] > 0
        assert d['chained_measured_over_modeled'] > 0
        assert isinstance(d['model_agrees'], bool)
    # model-mode exports carry no delta (nothing was measured)
    model2 = export_cnn(params, cfg, use_pallas=True, calibrate=x)
    assert model2.plan.summary()['lowering_cost_delta'] == {}
