"""Serving-runtime subsystem tests: the stage-resumable export must be
bit-exact vs the monolithic serving fn (and account its kernel launches),
the continuous-batching scheduler must drain any trace with per-request
answers bit-exact vs the request-alone oracle at fixed slot geometry, and
ChainState must round-trip through checkpoint/chain_io.py so the model
registry can load what Pipeline.run persisted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn import RESNET8_CIFAR
from repro.core.export import (QAct, calibrate_exit_threshold, export_cnn,
                               export_chain)
from repro.core.family import CNNFamily
from repro.core.passes import ChainState
from repro.data import SyntheticImages
from repro.kernels.tiling import batch_slots
from repro.serving import (Completion, ContinuousBatchScheduler,
                           ModelRegistry, Request, RequestQueue,
                           ServingMetrics, StaticBatchScheduler,
                           exit_decisions, percentile)

SLOTS = 8


@pytest.fixture(scope='module')
def family():
    return CNNFamily(SyntheticImages())


@pytest.fixture(scope='module')
def exported(family):
    """Int8-resident export with exit heads (the scheduler's contract)."""
    base = RESNET8_CIFAR
    params = family.init(jax.random.key(0), base)
    params, cfg = family.add_exits(jax.random.key(2), params, base,
                                   family.default_exit_points(base))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    return export_cnn(params, cfg, calibrate=calib), cfg


def _trace(n, rate=2000.0, seed=0):
    xs = jax.random.normal(jax.random.key(11), (max(n, 1), 32, 32, 3))
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(i, xs[i], float(t[i])) for i in range(n)]


def _oracle(model, x, threshold):
    """Monolithic fn_exits on the request ALONE at the slot geometry."""
    xb = jnp.concatenate([x[None],
                          jnp.zeros((SLOTS - 1,) + x.shape, x.dtype)])
    logits, exits = model.fn_exits(model.params, xb)
    stage, ans = exit_decisions(logits, exits, threshold)
    return int(stage[0]), ans[0]


# -------------------------------------------------- stage-resumable export


def test_stage_split_bit_exact_vs_monolithic(exported):
    model, cfg = exported
    assert model.n_stages == len(cfg.exit_stages) + 1
    x = jax.random.normal(jax.random.key(5), (SLOTS, 32, 32, 3))
    logits, exits = model.fn_exits(model.params, x)
    s_logits, s_exits = model.serve_stages(x)
    assert set(s_exits) == set(exits)
    for s in exits:
        np.testing.assert_array_equal(np.asarray(s_exits[s]),
                                      np.asarray(exits[s]))
    np.testing.assert_array_equal(np.asarray(s_logits), np.asarray(logits))


def test_stage_carry_is_int8_on_resident_plan(exported):
    model, _ = exported
    x = jax.random.normal(jax.random.key(5), (SLOTS, 32, 32, 3))
    carry = x
    for k in range(model.n_stages - 1):
        _, carry = model.run_stage(k, carry)
        assert isinstance(carry, QAct), 'resident carry must stay QAct'
        assert carry.q.dtype == jnp.int8
        assert isinstance(carry.scale, float)


def test_stage_split_launch_count(exported):
    """Sum of pallas_call launches across the stage segments == the
    monolithic fn_exits launch count: the split re-partitions the layer
    plan, it must not add or drop kernel launches."""
    _, cfg = exported
    params = CNNFamily(SyntheticImages()).init(jax.random.key(0),
                                               RESNET8_CIFAR)
    params, cfg = CNNFamily(SyntheticImages()).add_exits(
        jax.random.key(2), params, RESNET8_CIFAR,
        (0, 1))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=True, calibrate=x)

    def _count(jaxpr):
        n = 0
        for e in jaxpr.eqns:
            n += e.primitive.name == 'pallas_call'
            for v in e.params.values():
                if hasattr(v, 'jaxpr'):
                    n += _count(v.jaxpr)
                elif hasattr(v, 'eqns'):
                    n += _count(v)
        return n

    mono = _count(jax.make_jaxpr(
        lambda p, x: model.fn_exits(p, x))(model.params, x).jaxpr)
    carry, total = x, 0
    for k in range(model.n_stages):
        jx = jax.make_jaxpr(
            lambda p, h, _k=k: model.stage_fns[_k](p, h))(model.params,
                                                          carry)
        total += _count(jx.jaxpr)
        if k < model.n_stages - 1:
            _, carry = model.run_stage(k, carry)
    assert total == mono > 0


def test_run_stage_requires_exit_heads():
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = CNNFamily(SyntheticImages()).init(jax.random.key(0), cfg)
    model = export_cnn(params, cfg)
    assert model.n_stages == 0
    with pytest.raises(ValueError, match='without exit heads'):
        model.run_stage(0, jnp.zeros((1, 32, 32, 3)))
    with pytest.raises(ValueError, match='exit boundaries'):
        ContinuousBatchScheduler(model, slots=SLOTS)


# ------------------------------------------------------ batched early exit


def test_serve_early_exit_empty_batch(exported):
    model, _ = exported
    pred, stage = model.serve_early_exit(jnp.zeros((0, 32, 32, 3)))
    assert pred.shape == (0,) and stage.shape == (0,)


def test_serve_early_exit_threshold_none_uses_calibrated(exported):
    model, _ = exported
    x = jax.random.normal(jax.random.key(9), (SLOTS, 32, 32, 3))
    model.exit_threshold = 2.0            # impossible: nothing exits
    try:
        _, stage = model.serve_early_exit(x)
        assert bool(jnp.all(stage == -1))
        model.exit_threshold = -1.0       # everything exits at head 1
        _, stage = model.serve_early_exit(x)
        assert bool(jnp.all(stage == min(model.cfg.exit_stages)))
    finally:
        model.exit_threshold = 0.9


def test_scheduler_all_exit_and_none_exit(exported):
    model, cfg = exported
    reqs = _trace(2 * SLOTS)
    # threshold 2.0: nobody exits — every request runs all segments
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=2.0,
        stage_costs=[1e-3] * model.n_stages).run_trace(reqs)
    assert len(comp) == len(reqs)
    assert all(c.exit_stage == -1 for c in comp.values())
    s = met.summary()
    assert s['exit_fraction'] == 0.0
    assert all(str(k) in s['n_batches'] for k in range(model.n_stages))
    # threshold -1.0: everyone exits at the FIRST head; deeper segments
    # never execute (the compute early exit is supposed to save)
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=-1.0,
        stage_costs=[1e-3] * model.n_stages).run_trace(reqs)
    first = min(cfg.exit_stages)
    assert all(c.exit_stage == first for c in comp.values())
    s = met.summary()
    assert s['exit_fraction'] == 1.0
    assert set(s['n_batches']) == {'0'}, 'later segments must not run'


def test_scheduler_empty_trace(exported):
    model, _ = exported
    comp, met = ContinuousBatchScheduler(
        model, slots=SLOTS,
        stage_costs=[1e-3] * model.n_stages).run_trace([])
    assert comp == {}
    assert met.summary()['n_requests'] == 0


def test_scheduler_threshold_none_falls_back_to_model(exported):
    model, _ = exported
    model.exit_threshold = 2.0
    try:
        sched = ContinuousBatchScheduler(model, slots=SLOTS)
        assert sched.threshold == 2.0
    finally:
        model.exit_threshold = 0.9


def test_scheduler_drains_and_matches_request_alone_oracle(exported):
    """The tentpole contract: under a Poisson trace with compaction and
    backfill, every request's answer (exit stage AND logits) is bit-exact
    vs the monolithic model serving that request alone at the same slot
    geometry — batch composition never leaks into results."""
    model, _ = exported
    x8 = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    thr = calibrate_exit_threshold(model, x8)
    reqs = _trace(3 * SLOTS + 5)          # partial final batch too
    sched = ContinuousBatchScheduler(model, slots=SLOTS, threshold=thr,
                                     stage_costs=[1e-3] * model.n_stages)
    comp, met = sched.run_trace(reqs)
    assert len(comp) == len(reqs), 'queue not drained'
    for r in reqs:
        stage, ans = _oracle(model, r.x, thr)
        assert comp[r.rid].exit_stage == stage
        np.testing.assert_array_equal(comp[r.rid].logits, ans)
        assert comp[r.rid].pred == int(ans.argmax())
        assert comp[r.rid].latency >= 0.0
    s = met.summary()
    assert s['n_requests'] == len(reqs)
    assert 0.0 < s['exit_fraction'] <= 1.0
    assert s['throughput_rps'] > 0


def test_static_scheduler_agrees_with_compacting(exported):
    model, _ = exported
    x8 = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    thr = calibrate_exit_threshold(model, x8)
    reqs = _trace(2 * SLOTS)
    c_comp, _ = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=thr,
        stage_costs=[1e-3] * model.n_stages).run_trace(reqs)
    s_comp, _ = StaticBatchScheduler(
        model, slots=SLOTS, threshold=thr, batch_cost=3e-3).run_trace(reqs)
    for r in reqs:
        assert c_comp[r.rid].exit_stage == s_comp[r.rid].exit_stage
        np.testing.assert_array_equal(c_comp[r.rid].logits,
                                      s_comp[r.rid].logits)


def test_scheduler_wall_clock_mode(exported):
    """stage_costs=None times real executions; latencies stay ordered."""
    model, _ = exported
    reqs = _trace(SLOTS)
    comp, _ = ContinuousBatchScheduler(model,
                                       slots=SLOTS).run_trace(reqs)
    assert len(comp) == SLOTS
    assert all(c.t_done >= c.t_arrival for c in comp.values())


# ----------------------------------------------------- queue and metrics


def test_request_queue_time_gated():
    q = RequestQueue([Request(0, None, 0.0), Request(1, None, 1.0),
                      Request(2, None, 2.0)])
    assert q.pop_ready(0.5, 8) == [Request(0, None, 0.0)]
    assert q.next_arrival() == 1.0
    assert [r.rid for r in q.pop_ready(5.0, 1)] == [1]
    with pytest.raises(ValueError, match='arrival order'):
        q.push(Request(3, None, 0.5))
    assert len(q) == 1


def test_metrics_percentiles_and_occupancy():
    m = ServingMetrics()
    for i, lat in enumerate([0.01, 0.02, 0.03, 0.04]):
        m.record_completion(Completion(rid=i, logits=None, pred=0,
                                       exit_stage=(0 if i < 3 else -1),
                                       t_arrival=0.0, t_done=lat))
    m.record_batch(0, 4, 8)
    m.record_batch(1, 2, 8)
    s = m.summary()
    assert s['p50_latency_s'] == pytest.approx(0.025)
    assert s['p99_latency_s'] == pytest.approx(percentile(
        [0.01, 0.02, 0.03, 0.04], 99))
    assert s['exit_fraction'] == 0.75
    assert s['batch_occupancy'] == {'0': 0.5, '1': 0.25}
    assert percentile([], 99) == 0.0


def test_latency_splits_into_queue_wait_and_execute(exported):
    """Both schedulers stamp Completion.t_start at first dispatch, so
    every latency decomposes exactly into queue-wait + execute and the
    summary reports both percentile families."""
    model, _ = exported
    reqs = _trace(2 * SLOTS, rate=5000.0)
    costs = [4e-3, 2e-3, 1e-3]
    for sched in (ContinuousBatchScheduler(model, slots=SLOTS,
                                           stage_costs=costs),
                  StaticBatchScheduler(model, slots=SLOTS,
                                       batch_cost=sum(costs))):
        comp, met = sched.run_trace(reqs)
        assert len(comp) == len(reqs)
        for c in comp.values():
            assert c.t_start is not None
            assert c.t_arrival <= c.t_start <= c.t_done
            assert c.queue_wait + c.execute == pytest.approx(c.latency)
        s = met.summary()
        for key in ('p50_queue_wait_s', 'p99_queue_wait_s',
                    'p50_execute_s', 'p99_execute_s'):
            assert s[key] >= 0.0
        assert s['p50_queue_wait_s'] + s['p50_execute_s'] > 0.0
        # on the simulated clock execute time is bounded by full depth
        assert s['p99_execute_s'] <= sum(costs) + 1e-9
    # a queue backlog shows up in queue-wait, not execute: the 2nd batch
    # of a near-simultaneous burst waits for the 1st
    burst = _trace(2 * SLOTS, rate=10 ** 6)
    _, met = ContinuousBatchScheduler(model, slots=SLOTS,
                                      stage_costs=costs).run_trace(burst)
    assert met.summary()['p99_queue_wait_s'] >= costs[0]


def test_batch_slots_geometry():
    assert batch_slots(1) == 8
    assert batch_slots(8) == 8
    assert batch_slots(9) == 16
    assert batch_slots(0) == 8            # never an empty geometry
    assert batch_slots(33, mult=8) == 40


# --------------------------------------- checkpointing + model registry


def _chain_state(family, with_factored=True):
    base = RESNET8_CIFAR
    params = family.init(jax.random.key(0), base)
    if with_factored:
        params, _, _ = family.factorize(params, base, energy=0.6,
                                        min_rank=2)
    params, cfg = family.add_exits(jax.random.key(2), params, base,
                                   family.default_exit_points(base))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    return ChainState(family=family, cfg=cfg, params=params,
                      key=jax.random.key(7), base_bitops=1e9, base_bits=2e6,
                      prune_scale=0.7, lowrank_scale=0.5,
                      exit_probs={0: 0.25, 1: 0.5}, exit_threshold=0.42,
                      dyn_accuracy=0.5,
                      history=[{'pass': 'baseline', 'acc': 0.5}])


def test_chain_state_checkpoint_roundtrip(family, tmp_path):
    from repro.checkpoint import load_chain_state, save_chain_state
    st = _chain_state(family)
    save_chain_state(str(tmp_path), st, step=2)
    got, step = load_chain_state(str(tmp_path), family)
    assert step == 2
    assert got.cfg == st.cfg
    assert got.exit_threshold == 0.42
    assert got.exit_probs == {0: 0.25, 1: 0.5}
    assert got.mac_scale == pytest.approx(st.mac_scale)
    assert got.history == st.history
    assert np.array_equal(jax.random.key_data(got.key),
                          jax.random.key_data(st.key))
    a = jax.tree_util.tree_leaves(st.params)
    b = jax.tree_util.tree_leaves(got.params)
    assert len(a) == len(b)               # factored {'u','v'} trees survive
    assert all(x.dtype == y.dtype and np.array_equal(x, y)
               for x, y in zip(a, b))
    # the round-tripped state serves identically
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    np.testing.assert_array_equal(
        np.asarray(family.logits(st.params, st.cfg, x)),
        np.asarray(family.logits(got.params, got.cfg, x)))


def test_pipeline_checkpoint_resume(family, tmp_path):
    """Pipeline.run(checkpoint_dir=...) persists after every pass and a
    re-run resumes from disk instead of re-applying passes."""
    from repro.checkpoint.manager import latest_step
    from repro.core import registry
    from repro.core.chain import Pipeline
    from repro.core.passes import Trainer

    applied = []
    orig = registry.get_pass('Q')

    def counting_q(state, hp, trainer):
        applied.append('Q')
        return orig.fn(state, hp, trainer)

    fast = Trainer(batch=8, steps=1, eval_n=1, eval_batch=16)
    st0 = _chain_state(family, with_factored=False)
    registry.unregister('Q')
    registry.register(registry.CompressionPass(
        'Q', orig.name, orig.kind, orig.granularity, orig.hp_cls,
        counting_q))
    try:
        pipe = Pipeline.from_sequence('Q')
        out = pipe.run(family, st0.cfg, fast, state=st0,
                       checkpoint_dir=str(tmp_path))
        assert applied == ['Q']
        assert latest_step(str(tmp_path)) == 1
        # resume: the pass is already on disk, fn must NOT run again
        out2 = pipe.run(family, st0.cfg, fast,
                        checkpoint_dir=str(tmp_path))
        assert applied == ['Q']
        np.testing.assert_array_equal(
            np.asarray(jax.tree_util.tree_leaves(out.params)[0]),
            np.asarray(jax.tree_util.tree_leaves(out2.params)[0]))
        # a DIFFERENT pipeline must refuse this checkpoint, not silently
        # skip passes it never ran
        with pytest.raises(ValueError, match='produced by passes'):
            Pipeline.from_sequence('E').run(family, st0.cfg, fast,
                                            checkpoint_dir=str(tmp_path))
    finally:
        registry.unregister('Q')
        registry.register(orig)


def test_model_registry_loads_checkpointed_chain(family, tmp_path):
    from repro.checkpoint import save_chain_state
    st = _chain_state(family)
    save_chain_state(str(tmp_path), st, step=0)
    reg = ModelRegistry()
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    model = reg.load('resnet8', str(tmp_path), family, calibrate=calib)
    assert 'resnet8' in reg and reg.names() == ['resnet8']
    assert reg.get('resnet8') is model
    assert model.exit_threshold == 0.42   # chain threshold threaded through
    assert model.n_stages == len(st.cfg.exit_stages) + 1
    # a registry-loaded model drives the scheduler end to end
    comp, _ = ContinuousBatchScheduler(
        model, slots=SLOTS, threshold=calibrate_exit_threshold(model, calib),
        stage_costs=[1e-3] * model.n_stages).run_trace(_trace(SLOTS))
    assert len(comp) == SLOTS
    with pytest.raises(ValueError, match='already registered'):
        reg.register('resnet8', model)
    with pytest.raises(KeyError):
        reg.get('missing')


def test_export_chain_stage_fns_from_state(family):
    """export_chain gives the registry path the same stage-split API."""
    st = _chain_state(family, with_factored=False)
    calib = jax.random.normal(jax.random.key(3), (SLOTS, 32, 32, 3))
    model = export_chain(st, calibrate=calib)
    assert model.n_stages == len(st.cfg.exit_stages) + 1
    x = jax.random.normal(jax.random.key(5), (SLOTS, 32, 32, 3))
    logits, _ = model.fn_exits(model.params, x)
    s_logits, _ = model.serve_stages(x)
    np.testing.assert_array_equal(np.asarray(s_logits), np.asarray(logits))
