"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret mode on CPU).  The hypothesis property tests live in
tests/test_kernels_property.py so this module collects without the dep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.depthwise_conv import depthwise_conv, fits_depthwise
from repro.kernels.fake_quant import fake_quant
from repro.kernels.quant_matmul import quant_matmul


# ------------------------------------------------------------- quant_matmul


@pytest.mark.parametrize('M,K,N', [(128, 256, 128), (256, 512, 384),
                                   (64, 128, 256), (128, 1024, 512),
                                   (32, 96, 160)])
@pytest.mark.parametrize('out_dtype', [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(M, K, N, out_dtype):
    k = jax.random.key(M * 7 + N)
    xq = jax.random.randint(k, (M, K), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (K, N), -128, 128,
                            jnp.int8)
    sx = jax.random.uniform(jax.random.fold_in(k, 2), (M,), jnp.float32,
                            1e-3, 1e-2)
    sw = jax.random.uniform(jax.random.fold_in(k, 3), (N,), jnp.float32,
                            1e-3, 1e-2)
    out = quant_matmul(xq, wq, sx, sw, out_dtype=out_dtype, interpret=True)
    expect = ref.quant_matmul_ref(xq, wq, sx, sw, out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=1e-2, atol=1e-4)


@pytest.mark.parametrize('bm,bn,bk', [(64, 64, 64), (128, 128, 128),
                                      (32, 128, 256)])
def test_quant_matmul_block_shapes(bm, bn, bk):
    k = jax.random.key(0)
    xq = jax.random.randint(k, (128, 256), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (256, 128), -128, 128,
                            jnp.int8)
    sx = jnp.full((128,), 0.01)
    sw = jnp.full((128,), 0.02)
    out = quant_matmul(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk, interpret=True)
    expect = ref.quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4)


# --------------------------------------------------------------- fake_quant


@pytest.mark.parametrize('K,N', [(128, 128), (512, 384), (96, 640),
                                 (2048, 256)])
@pytest.mark.parametrize('bits', [8, 4, 2])
def test_fake_quant_sweep(K, N, bits):
    w = jax.random.normal(jax.random.key(K + bits), (K, N))
    out = fake_quant(w, bits=bits, interpret=True)
    expect = ref.fake_quant_ref(w, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- decode attention


@pytest.mark.parametrize('B,H,K,D,S', [(2, 8, 4, 64, 512), (1, 4, 4, 128, 256),
                                       (2, 16, 2, 64, 1024), (4, 8, 8, 128, 384)])
def test_decode_attention_sweep(B, H, K, D, S):
    k = jax.random.key(B * 31 + S)
    q = jax.random.normal(k, (B, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    valid = jnp.arange(S) < (S * 3 // 4)
    out = decode_attention(q, kk, vv, valid, s_blk=128, interpret=True)
    expect = ref.decode_attention_ref(q, kk, vv,
                                      jnp.broadcast_to(valid, (B, S)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_int8_dense_serving_accuracy():
    """End-to-end int8 serving path stays within ~1.5% of fp32."""
    k = jax.random.key(0)
    x = jax.random.normal(k, (64, 512))
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 256)) * 0.05
    y = ops.quantize_dense_int8(x, w)
    y_ref = x @ w
    rel = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
    assert rel < 0.015, rel


# ------------------------------------------------ int8-KV flash decode


@pytest.mark.parametrize('B,H,K,D,S', [(2, 8, 4, 64, 512),
                                       (1, 16, 8, 128, 256)])
def test_decode_attention_int8_kv(B, H, K, D, S):
    """int8-KV kernel == bf16 oracle run on the dequantized cache."""
    from repro.kernels.decode_attention import decode_attention_int8
    from repro.models.attention import kv_quantize, kv_dequantize
    k = jax.random.key(B * 13 + S)
    q = jax.random.normal(k, (B, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    kq, ks = kv_quantize(kk)
    vq, vs = kv_quantize(vv)
    valid = jnp.arange(S) < (S - 37)
    out = decode_attention_int8(q, kq, vq, ks, vs, valid, s_blk=128,
                                interpret=True)
    expect = ref.decode_attention_ref(
        q, kv_dequantize(kq, ks, jnp.float32),
        kv_dequantize(vq, vs, jnp.float32),
        jnp.broadcast_to(valid, (B, S)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------- depthwise_conv


def _dw_case(C, mult, seed=0, B=2, H=9, W=11, kh=3, kw=3):
    k = jax.random.key(seed)
    n = C * mult
    x = jax.random.randint(k, (B, H, W, C), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(k, 1), (kh, kw, 1, n),
                           -128, 128, jnp.int8)
    sw = jax.random.uniform(jax.random.fold_in(k, 2), (n,), jnp.float32,
                            1e-3, 1e-2)
    b = jax.random.normal(jax.random.fold_in(k, 3), (n,)) * 0.1
    return x, w, sw, b


@pytest.mark.parametrize('C,mult', [(32, 1), (33, 1), (7, 2), (130, 1),
                                    (8, 4)])
@pytest.mark.parametrize('stride', [1, 2])
def test_depthwise_conv_bit_exact_oracle(C, mult, stride):
    """Direct depthwise kernel == lax.conv oracle on raw integer codes,
    bit-for-bit (not allclose): strides, channel multipliers, odd/wide
    channel counts all pad value-exactly."""
    x, w, sw, b = _dw_case(C, mult, seed=C * 7 + stride)
    out = depthwise_conv(x, w, 0.013, sw, b, stride=stride, relu=True,
                         interpret=True)
    expect = ref.depthwise_conv_ref(x, w, 0.013, sw, b, stride=stride,
                                    relu=True)
    assert out.shape == expect.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize('stride', [1, 2])
def test_depthwise_conv_requantize_epilogue(stride):
    """out_scale produces int8 on the static grid, bit-exact with the
    oracle's requantize — the int8-in/int8-out serving contract."""
    x, w, sw, b = _dw_case(32, 1, seed=5)
    out = depthwise_conv(x, w, 0.01, sw, b, stride=stride, out_scale=0.02,
                         interpret=True)
    expect = ref.depthwise_conv_ref(x, w, 0.01, sw, b, stride=stride,
                                    out_scale=0.02)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_depthwise_conv_no_bias_and_fits():
    """bias=None serves (zero bias injected); fits_depthwise admits exactly
    the per-group-depth-1 weight shapes."""
    x, w, sw, _ = _dw_case(16, 1, seed=9)
    out = depthwise_conv(x, w, 0.01, sw, None, interpret=True)
    expect = ref.depthwise_conv_ref(x, w, 0.01, sw, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    assert fits_depthwise((3, 3, 1, 64)) and fits_depthwise((5, 5, 1, 7))
    assert not fits_depthwise((3, 3, 4, 64))   # per-group depth > 1
    assert not fits_depthwise((3, 3, 64))      # not a conv weight


def test_depthwise_conv_static_entry():
    """ops.depthwise_conv_static (the resident-path entry) matches its ref
    on both backends — the kernel path bit-exactly."""
    x, w, sw, b = _dw_case(24, 1, seed=11)
    expect = ref.depthwise_conv_ref(x, w, 0.012, sw, b, stride=2,
                                    out_scale=0.03)
    got_k = ops.depthwise_conv_static(x, w, sw, b, sx=0.012, stride=2,
                                      out_scale=0.03, use_pallas=True)
    got_r = ops.depthwise_conv_static(x, w, sw, b, sx=0.012, stride=2,
                                      out_scale=0.03, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(expect))
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(expect))
