"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret mode on CPU).  The hypothesis property tests live in
tests/test_kernels_property.py so this module collects without the dep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fake_quant import fake_quant
from repro.kernels.quant_matmul import quant_matmul


# ------------------------------------------------------------- quant_matmul


@pytest.mark.parametrize('M,K,N', [(128, 256, 128), (256, 512, 384),
                                   (64, 128, 256), (128, 1024, 512),
                                   (32, 96, 160)])
@pytest.mark.parametrize('out_dtype', [jnp.float32, jnp.bfloat16])
def test_quant_matmul_sweep(M, K, N, out_dtype):
    k = jax.random.key(M * 7 + N)
    xq = jax.random.randint(k, (M, K), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (K, N), -128, 128,
                            jnp.int8)
    sx = jax.random.uniform(jax.random.fold_in(k, 2), (M,), jnp.float32,
                            1e-3, 1e-2)
    sw = jax.random.uniform(jax.random.fold_in(k, 3), (N,), jnp.float32,
                            1e-3, 1e-2)
    out = quant_matmul(xq, wq, sx, sw, out_dtype=out_dtype, interpret=True)
    expect = ref.quant_matmul_ref(xq, wq, sx, sw, out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=1e-2, atol=1e-4)


@pytest.mark.parametrize('bm,bn,bk', [(64, 64, 64), (128, 128, 128),
                                      (32, 128, 256)])
def test_quant_matmul_block_shapes(bm, bn, bk):
    k = jax.random.key(0)
    xq = jax.random.randint(k, (128, 256), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (256, 128), -128, 128,
                            jnp.int8)
    sx = jnp.full((128,), 0.01)
    sw = jnp.full((128,), 0.02)
    out = quant_matmul(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk, interpret=True)
    expect = ref.quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4)


# --------------------------------------------------------------- fake_quant


@pytest.mark.parametrize('K,N', [(128, 128), (512, 384), (96, 640),
                                 (2048, 256)])
@pytest.mark.parametrize('bits', [8, 4, 2])
def test_fake_quant_sweep(K, N, bits):
    w = jax.random.normal(jax.random.key(K + bits), (K, N))
    out = fake_quant(w, bits=bits, interpret=True)
    expect = ref.fake_quant_ref(w, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- decode attention


@pytest.mark.parametrize('B,H,K,D,S', [(2, 8, 4, 64, 512), (1, 4, 4, 128, 256),
                                       (2, 16, 2, 64, 1024), (4, 8, 8, 128, 384)])
def test_decode_attention_sweep(B, H, K, D, S):
    k = jax.random.key(B * 31 + S)
    q = jax.random.normal(k, (B, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    valid = jnp.arange(S) < (S * 3 // 4)
    out = decode_attention(q, kk, vv, valid, s_blk=128, interpret=True)
    expect = ref.decode_attention_ref(q, kk, vv,
                                      jnp.broadcast_to(valid, (B, S)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_int8_dense_serving_accuracy():
    """End-to-end int8 serving path stays within ~1.5% of fp32."""
    k = jax.random.key(0)
    x = jax.random.normal(k, (64, 512))
    w = jax.random.normal(jax.random.fold_in(k, 1), (512, 256)) * 0.05
    y = ops.quantize_dense_int8(x, w)
    y_ref = x @ w
    rel = float(jnp.max(jnp.abs(y - y_ref)) / jnp.max(jnp.abs(y_ref)))
    assert rel < 0.015, rel


# ------------------------------------------------ int8-KV flash decode


@pytest.mark.parametrize('B,H,K,D,S', [(2, 8, 4, 64, 512),
                                       (1, 16, 8, 128, 256)])
def test_decode_attention_int8_kv(B, H, K, D, S):
    """int8-KV kernel == bf16 oracle run on the dequantized cache."""
    from repro.kernels.decode_attention import decode_attention_int8
    from repro.models.attention import kv_quantize, kv_dequantize
    k = jax.random.key(B * 13 + S)
    q = jax.random.normal(k, (B, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, D))
    kq, ks = kv_quantize(kk)
    vq, vs = kv_quantize(vv)
    valid = jnp.arange(S) < (S - 37)
    out = decode_attention_int8(q, kq, vq, ks, vs, valid, s_blk=128,
                                interpret=True)
    expect = ref.decode_attention_ref(
        q, kv_dequantize(kq, ks, jnp.float32),
        kv_dequantize(vq, vs, jnp.float32),
        jnp.broadcast_to(valid, (B, S)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)
