"""Checkpoint / fault-tolerance / elastic / straggler subsystem tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.runtime import (FaultTolerantLoop, SimulatedFailure,
                           StragglerMonitor, reshard_tree)


def tree_eq(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def test_checkpoint_roundtrip(tmp_path):
    tree = {'w': jnp.arange(12.0).reshape(3, 4),
            'nested': {'b': jnp.ones((5,), jnp.bfloat16)},
            'lst': [jnp.zeros((2,)), jnp.full((2, 2), 7)]}
    save_checkpoint(str(tmp_path), 3, tree)
    out, step = load_checkpoint(str(tmp_path), None, tree)
    assert step == 3 and tree_eq(tree, out)


def test_checkpoint_atomicity_keeps_last_good(tmp_path):
    tree = {'x': jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # a torn write: tmp dir left behind must be ignored
    os.makedirs(tmp_path / 'step_00000002.tmp')
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(5):
        mgr.save(s, {'x': jnp.full((3,), s)})
    mgr.wait()
    steps = sorted(int(d.split('_')[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    out, step = mgr.restore_latest({'x': jnp.zeros((3,))})
    assert step == 4 and float(out['x'][0]) == 4


def test_restore_latest_falls_back_past_corruption(tmp_path):
    """A committed step that rots after the rename (truncated manifest or
    npz) must not kill the restore: restore_latest walks back to the most
    recent readable step, and raises FileNotFoundError only when every
    committed step is corrupt."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    for s in range(3):
        mgr.save(s, {'x': jnp.full((3,), s)})
    # truncate the newest step's manifest mid-file
    with open(tmp_path / 'step_00000002' / 'manifest.json', 'r+') as f:
        f.truncate(10)
    out, step = mgr.restore_latest({'x': jnp.zeros((3,))})
    assert step == 1 and float(out['x'][0]) == 1
    # rot the npz of step 1 too — fall back two steps
    with open(tmp_path / 'step_00000001' / 'proc_0.npz', 'w') as f:
        f.write('not a zip')
    out, step = mgr.restore_latest({'x': jnp.zeros((3,))})
    assert step == 0 and float(out['x'][0]) == 0
    # every committed step corrupt -> FileNotFoundError, not a crash
    os.remove(tmp_path / 'step_00000000' / 'manifest.json')
    with pytest.raises(FileNotFoundError, match='all corrupt'):
        mgr.restore_latest({'x': jnp.zeros((3,))})


def test_fault_tolerant_loop_recovers(tmp_path):
    """Inject failures at fixed steps; the loop must restore and finish with
    the same final state a failure-free run produces (determinism)."""
    def step_fn(state, batch):
        return {'acc': state['acc'] + batch}, {}

    def batch_fn(step):
        return jnp.asarray(float(step))

    def run(inject):
        fired = set()

        def injector(step):
            if inject and step in (7, 13) and step not in fired:
                fired.add(step)
                raise SimulatedFailure(f'node lost at {step}')

        d = tmp_path / ('ft_inject' if inject else 'ft_clean')
        loop = FaultTolerantLoop(step_fn=step_fn, batch_fn=batch_fn,
                                 ckpt=CheckpointManager(str(d), keep=3,
                                                        async_save=False),
                                 ckpt_every=5, failure_injector=injector)
        state, end = loop.run({'acc': jnp.asarray(0.0)}, 0, 20)
        return state, loop.restarts

    clean, r0 = run(False)
    faulty, r1 = run(True)
    assert r0 == 0 and r1 == 2
    assert float(clean['acc']) == float(faulty['acc'])


def test_poison_pill_detection(tmp_path):
    def bad_step(state, batch):
        raise RuntimeError('deterministic bug')

    loop = FaultTolerantLoop(step_fn=bad_step, batch_fn=lambda s: None,
                             ckpt=CheckpointManager(str(tmp_path),
                                                    async_save=False),
                             ckpt_every=5, max_restarts=3)
    with pytest.raises(RuntimeError, match='poison pill'):
        loop.run({'x': jnp.zeros(())}, 0, 5)


def test_elastic_reshard_roundtrip():
    """Reshard a tree across different 1-device 'meshes' (semantics check;
    the 256/512-way placement is exercised by the dry-run)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ('data', 'model'))
    tree = {'w': jnp.arange(16.0).reshape(4, 4)}
    sh = {'w': NamedSharding(mesh, P(None, 'model'))}
    out = reshard_tree(tree, sh)
    assert tree_eq(tree, out)
    assert out['w'].sharding == sh['w']


def test_straggler_monitor_reassigns_and_evicts():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, evict_after=2,
                           spares=[9])
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert mon.observe(base) == []
    slow = {**base, 2: 5.0}
    acts = mon.observe(slow)
    assert ('reassign', 2, 9) in acts
    assert mon.data_host_id(2) == 9
    acts = mon.observe(slow)
    assert ('evict', 2) in acts


def test_grad_compression_error_feedback():
    from repro.optim.compression import int8_compress_grads, int8_decompress
    g = {'w': jnp.asarray([0.1, -0.2, 0.3001, 1.0])}
    q, s, r = int8_compress_grads(g, None)
    deq = int8_decompress(q, s)
    # error feedback: residual exactly equals quantization error
    np.testing.assert_allclose(np.asarray(deq['w'] + r['w']),
                               np.asarray(g['w']), rtol=1e-6)
    # second round: accumulated residual pushes values through
    q2, s2, r2 = int8_compress_grads(g, r)
    total = np.asarray(int8_decompress(q2, s2)['w'] + r2['w'])
    np.testing.assert_allclose(total, 2 * np.asarray(g['w']) -
                               np.asarray(deq['w']), rtol=1e-5)
