import os
import sys

# Tests run single-device (the dry-run, and only the dry-run, forces 512
# host devices).  Keep XLA quiet and deterministic.
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))
