import os
import subprocess
import sys

# Tests run single-device in-process (multi-device tests go through the
# forced_devices subprocess fixture below).  Keep XLA quiet and
# deterministic.
os.environ.setdefault('JAX_PLATFORMS', 'cpu')
sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))

import pytest  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_FORCE_FLAG = '--xla_force_host_platform_device_count'


def backend_initialized() -> bool:
    """True once jax has instantiated a backend in THIS process — the
    device count is locked from then on, so XLA_FLAGS edits are silently
    ignored."""
    if 'jax' not in sys.modules:
        return False
    from jax._src import xla_bridge
    return xla_bridge.backends_are_initialized()


def _merge_xla_flags(flags: str, n: int) -> str:
    kept = [f for f in flags.split() if not f.startswith(_FORCE_FLAG)]
    return ' '.join(kept + [f'{_FORCE_FLAG}={n}'])


def force_host_device_count(n: int) -> None:
    """Force ``n`` virtual host devices in THIS process.

    Legal only before jax initializes its backend: afterwards the count
    is locked and mutating ``XLA_FLAGS`` does nothing — the historical
    test_moe_ep.py bug this guard exists to catch (it overwrote the env
    var inside an embedded script; harmless there because the subprocess
    had not touched jax yet, but silently wrong anywhere else).  Raises
    ``RuntimeError`` instead of failing silently; tests that need a
    different device count use the :func:`forced_devices` fixture, which
    runs them in a fresh subprocess.
    """
    if backend_initialized():
        raise RuntimeError(
            f'cannot force {n} host devices: the jax backend is already '
            f'initialized in this process and its device count is '
            f'locked — run under the forced_devices subprocess fixture '
            f'instead')
    os.environ['JAX_PLATFORMS'] = 'cpu'
    os.environ['XLA_FLAGS'] = _merge_xla_flags(
        os.environ.get('XLA_FLAGS', ''), n)


def forced_device_env(n: int) -> dict:
    """A subprocess environment with ``n`` forced host devices: CPU
    platform, merged ``XLA_FLAGS``, ``PYTHONPATH`` covering src/."""
    env = dict(os.environ, JAX_PLATFORMS='cpu')
    env['XLA_FLAGS'] = _merge_xla_flags(env.get('XLA_FLAGS', ''), n)
    path = env.get('PYTHONPATH', '')
    src = os.path.join(REPO_ROOT, 'src')
    env['PYTHONPATH'] = src + (os.pathsep + path if path else '')
    return env


@pytest.fixture(scope='session')
def forced_devices():
    """Run a python script under ``n`` forced virtual host devices in a
    fresh subprocess (the only safe way once this process's backend is
    up).  Returns the CompletedProcess; asserts on failure with the
    child's output so the report is readable."""
    def run(script: str, n: int = 8, timeout: float = 600.0,
            check: bool = True):
        r = subprocess.run([sys.executable, '-c', script],
                           env=forced_device_env(n), capture_output=True,
                           text=True, timeout=timeout, cwd=REPO_ROOT)
        if check:
            assert r.returncode == 0, (
                f'forced-{n}-device subprocess failed '
                f'(rc={r.returncode})\nstdout={r.stdout}\n'
                f'stderr={r.stderr[-4000:]}')
        return r
    return run
