"""Expert-parallel MoE (shard_map) must be numerically identical to the
dense single-device reference — run on 8 virtual host devices through
the shared ``forced_devices`` subprocess fixture (the device count is
locked at jax init, so multi-device tests cannot share this process)."""

SCRIPT = r'''
import jax, jax.numpy as jnp
assert len(jax.devices()) == 8, f"expected 8 forced devices, got {len(jax.devices())}"
from repro.configs import get_smoke_config
from repro.models.moe import _moe_block_dense, moe_block, init_moe
from repro.models.actsharding import make_mesh_policy, activation_sharding
mesh = jax.make_mesh((2, 4), ('data', 'model'))
bad = 0
for E, seed in [(4, 0), (2, 1), (8, 2), (3, 3)]:
    cfg = get_smoke_config('mixtral-8x7b').replace(
        n_experts=E, top_k=2, moe_d_ff=64, capacity_factor=8.0)
    p = init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 10), (4, 16, cfg.d_model)) * 0.3
    ref = _moe_block_dense(p, x, cfg)
    with mesh:
        with activation_sharding(make_mesh_policy(mesh)):
            out = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"E={E} err={err:.3e}")
    if err > 1e-5:
        bad += 1
# shared-expert + a2a (deepseek-style)
cfg = get_smoke_config('deepseek-v3-671b').replace(
    n_experts=8, top_k=2, moe_d_ff=64, n_shared_experts=1,
    capacity_factor=16.0)
p = init_moe(jax.random.key(5), cfg)
x = jax.random.normal(jax.random.key(6), (4, 16, cfg.d_model)) * 0.3
ref = _moe_block_dense(p, x, cfg)
with mesh:
    with activation_sharding(make_mesh_policy(mesh)):
        out = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
err = float(jnp.max(jnp.abs(out - ref)))
print(f"deepseek-style err={err:.3e}")
if err > 1e-5:
    bad += 1
raise SystemExit(bad)
'''


def test_moe_expert_parallel_matches_dense(forced_devices):
    forced_devices(SCRIPT, n=8)
