"""Static-analyzer tests: the rule registry round-trips like the pass
registry, every builtin rule is green on a clean resident export, RED on
its own deliberately-mutated export (repro/analysis/mutations.py — the
same fixtures the ci.sh gate runs), order-dag names the violated edge,
and reports serialize/attach/raise the way export_cnn and the serving
launcher rely on."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (AnalysisError, AnalysisReport, AnalysisRule,
                            Finding, check, get_rule, register_rule,
                            registered_rules, unregister_rule)
from repro.analysis.mutations import MUTANTS, _resnet_export
from repro.core import planner, registry


# ------------------------------------------------------------ rule registry


def test_rule_registry_round_trip():
    rule = AnalysisRule(key='always-green', severity='info', requires=(),
                        doc='fires nothing', fn=lambda ctx, r: [])
    register_rule(rule)
    try:
        assert get_rule('always-green') is rule
        assert 'always-green' in registered_rules()
        with pytest.raises(ValueError, match='already registered'):
            register_rule(rule)
        register_rule(rule, replace=True)          # explicit shadowing ok
        # an unconstrained rule runs even over an empty target
        rep = check(rules=('always-green',), target='nothing')
        assert rep.checked == ('always-green',) and rep.ok
    finally:
        assert unregister_rule('always-green') is rule
    assert 'always-green' not in registered_rules()
    with pytest.raises(KeyError, match='not registered'):
        unregister_rule('always-green')


@pytest.mark.parametrize('bad', [
    dict(key='CamelCase', severity='error', requires=(), doc='', fn=len),
    dict(key='x', severity='fatal', requires=(), doc='', fn=len),
    dict(key='x', severity='error', requires=('gpu',), doc='', fn=len),
    dict(key='x', severity='error', requires=(), doc='', fn=None),
])
def test_register_rule_validates(bad):
    with pytest.raises(ValueError):
        register_rule(AnalysisRule(**bad))


def test_get_rule_unknown():
    with pytest.raises(KeyError, match='unknown rule'):
        get_rule('no-such-rule')


def test_builtin_rules_registered():
    assert set(registered_rules()) >= {
        'int8-residency', 'vmem-fit', 'launch-budget', 'stage-carry',
        'order-dag', 'hlo-traffic'}


# ---------------------------------------------------- green on clean export


@pytest.fixture(scope='module')
def clean_pallas():
    model, _, _, x = _resnet_export(use_pallas=True, exits=True)
    return model, x


def test_clean_export_green_all_rules(clean_pallas):
    model, x = clean_pallas
    rep = check(model, x=x)
    assert rep.ok, str(rep)
    # every builtin rule either ran or was skipped with a visible reason
    covered = set(rep.checked) | {k for k, _ in rep.skipped}
    assert covered >= set(registered_rules()), str(rep)
    assert ('order-dag', 'target lacks sequence') in rep.skipped


def test_clean_jnp_export_green_and_skips_pallas_rules():
    model, _, _, x = _resnet_export(use_pallas=False)
    rep = check(model, x=x)
    assert rep.ok, str(rep)
    # launch-budget still enforces plan-internal consistency on jnp;
    # only the graph-counting vmem rule needs the pallas backend
    assert 'launch-budget' in rep.checked
    assert ('vmem-fit', 'target lacks pallas') in rep.skipped
    # hlo-traffic ran for real on the jnp backend and reported its ratio
    infos = [f for f in rep.by_rule('hlo-traffic') if f.severity == 'info']
    assert infos and 'predicted' in infos[0].message


# ------------------------------------------------------ red on every mutant


@pytest.mark.parametrize('key', sorted(MUTANTS))
def test_mutant_is_caught_by_exactly_its_rule(key):
    kwargs = MUTANTS[key]()
    assert kwargs['rules'] == (key,)       # verdict attributable to one rule
    rep = check(**kwargs)
    errs = [f for f in rep.by_rule(key) if f.severity == 'error']
    assert errs, f'{key} mutant produced no error finding:\n{rep}'
    assert not rep.ok
    with pytest.raises(AnalysisError):
        rep.raise_if_errors()


# ----------------------------------------------------------------- order-dag


def test_order_dag_reports_violated_edge():
    rep = check(sequence='QP')
    assert not rep.ok
    (f,) = rep.by_rule('order-dag')
    assert f.where == 'P->Q'
    assert "'Q' before 'P'" in f.message


def test_order_dag_accepts_theoretical_order_and_pipeline():
    from repro.core.chain import Pipeline
    assert check(sequence=planner.theoretical_order()).ok
    pipe = Pipeline.from_sequence('DPQE', verify_order=True)  # no raise
    assert pipe.verify_order().ok
    with pytest.raises(AnalysisError):
        Pipeline.from_sequence('QP', verify_order=True)
    # opting out keeps wrong orders constructible (pairwise experiments)
    assert Pipeline.from_sequence('QP').sequence == 'QP'


def test_order_dag_unknown_key_warns_not_errors():
    rep = check(sequence='DZ')
    assert rep.ok                          # warn-severity only
    assert any(f.severity == 'warn' and f.where == 'Z'
               for f in rep.by_rule('order-dag'))


def test_theoretical_dag_orders_distinct_classes_only():
    edges = planner.theoretical_dag()
    order = planner.theoretical_order()
    for a, b in edges:
        assert order.index(a) < order.index(b)
        assert registry.get_pass(a).rank[:2] != registry.get_pass(b).rank[:2]
    # same-class pair (L and Q: both static / sub-neuron) must be unordered
    if {'L', 'Q'} <= set(registry.registered_keys()):
        assert ('L', 'Q') not in edges and ('Q', 'L') not in edges
        assert check(sequence='QL').ok and check(sequence='LQ').ok


# --------------------------------------------------- report + export wiring


def test_report_serializes_to_json():
    rep = check(sequence='QP')
    d = rep.to_dict()
    assert json.loads(json.dumps(d)) == d
    assert d['ok'] is False and d['findings'][0]['rule'] == 'order-dag'
    assert 'FAIL' in str(rep) and 'P->Q' in str(rep)


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError, match='unknown severity'):
        Finding('r', 'fatal', 'm')


def test_unsatisfiable_rules_skip_visibly():
    rep = check()                          # no model, no sequence
    assert rep.checked == () and rep.ok
    assert {k for k, _ in rep.skipped} == set(registered_rules())


def test_export_cnn_verify_attaches_report():
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core.export import export_cnn
    from repro.models.cnn import init_cnn
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    with pytest.raises(ValueError, match='verify'):
        export_cnn(params, cfg, use_pallas=False, calibrate=x,
                   verify='bogus')
    m = export_cnn(params, cfg, use_pallas=False, calibrate=x,
                   verify='strict')       # clean export: strict must pass
    assert isinstance(m.analysis, AnalysisReport) and m.analysis.ok
    assert m.summary()['analysis']['ok'] is True
    # un-verified exports don't carry a report (and summary stays lean)
    m2 = export_cnn(params, cfg, use_pallas=False, calibrate=x)
    assert m2.analysis is None and 'analysis' not in m2.summary()


def test_strict_check_raises_with_report_attached(clean_pallas):
    model, x = clean_pallas
    probe = AnalysisRule(key='always-red', severity='error', requires=(),
                         doc='', fn=lambda ctx, r: [r.finding('boom')])
    register_rule(probe)
    try:
        with pytest.raises(AnalysisError) as ei:
            check(model, x=x, rules=('always-red',), strict=True)
        assert ei.value.report.by_rule('always-red')
    finally:
        unregister_rule('always-red')
