"""Build the EXPERIMENTS.md §Paper-results + §Perf tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.summarize
Writes experiments/summary.md (pasted into EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import os

PAPER = 'experiments/paper'
DRY = 'experiments/dryrun/pod'


def _load(name):
    p = os.path.join(PAPER, name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def _cell(tagged):
    p = os.path.join(DRY, tagged + '.json')
    if not os.path.exists(p):
        return None
    with open(p) as f:
        r = json.load(f)
    coll = sum(r['collective_bytes'].values())
    return {'flops': r['flops_per_device'],
            'compute_s': r['flops_per_device'] / 197e12,
            'bytes': r['bytes_per_device'],
            'args_gb': r['memory']['argument_bytes'] / 1e9,
            'mem_s': (2 * r['bytes_per_device']
                      + r['memory']['argument_bytes']) / 819e9,
            'coll_s': coll / 50e9}


def main():
    out = []
    pw = _load('pairwise_order.json')
    if pw:
        out.append('### Pairwise order experiments (Figs. 6-11)\n')
        out.append('| pair | winner | score A->B | score B->A |')
        out.append('|---|---|---|---|')
        # registry-generic: every 2-letter result entry is a pair; pairs
        # decided structurally (one order inapplicable) carry no scores
        for key, r in pw.items():
            if not (isinstance(r, dict) and len(key) == 2
                    and r.get('winner')):
                continue
            a, b = key
            sa, sb = (r.get('score_' + a + b), r.get('score_' + b + a))
            fmt = lambda s: f'{s:.4f}' if s is not None else 'structural'
            out.append(f"| {a}{b} | **{r['winner']}** "
                       f"| {fmt(sa)} | {fmt(sb)} |")
        out.append(f"\ntopological order: **{pw['topological_order']}**"
                   f" (theoretical: {pw.get('theoretical_order', '?')}, "
                   f"dropped weak edges: {pw.get('dropped_edges')})\n")
    sl = _load('sequence_law.json')
    if sl:
        out.append('### Sequence law (Table 1)\n')
        budgets = list(next(iter(sl['table'].values()))['budget_crs'])
        out.append('| sequence | ' + ' | '.join(budgets) + ' |')
        out.append('|---' * (len(budgets) + 1) + '|')
        for seq, row in sl['table'].items():
            cells = [f'{v:.0f}x' if v else '-'
                     for v in row['budget_crs'].values()]
            out.append(f'| {seq} | ' + ' | '.join(cells) + ' |')
        out.append(f"\nbaseline accuracy {sl['baseline_acc']:.3f}\n")
    for name, title in [('chain_cnn_archs.json',
                         'Full chain on CNN families (Tables 2-4)'),
                        ('chain_lm_archs.json',
                         'Full chain transferred to LMs (beyond paper)')]:
        ca = _load(name)
        if ca:
            out.append(f'### {title}\n')
            out.append('| model | baseline acc | final acc | BitOpsCR | CR |')
            out.append('|---|---|---|---|---|')
            for model, d in ca.items():
                if not (isinstance(d, dict) and 'history' in d):
                    continue                       # meta keys ('sequence')
                h0, h1 = d['history'][0], d['history'][-1]
                out.append(f"| {model} | {h0['acc']:.3f} | {h1['acc']:.3f} "
                           f"| {h1['BitOpsCR']:.0f}x | {h1['CR']:.1f}x |")
            out.append('')
    rp = _load('repeat_compression.json')
    if rp:
        out.append('### Repeating compression (Fig. 14)\n')
        out.append('| variant | acc | BitOpsCR |')
        out.append('|---|---|---|')
        for k, v in rp.items():
            out.append(f"| {k} | {v['acc']:.3f} | {v['BitOpsCR']:.1f}x |")
        out.append('')

    out.append('### §Perf cells (final, consistent measurement)\n')
    rows = [
        ('mixtral train_4k baseline', 'mixtral-8x7b__train_4k_base3'),
        ('mixtral train_4k EP', 'mixtral-8x7b__train_4k'),
        ('deepseek train_4k baseline', 'deepseek-v3-671b__train_4k_base3'),
        ('deepseek train_4k EP(a2a)', 'deepseek-v3-671b__train_4k'),
        ('qwen2 decode_32k baseline', 'qwen2-72b__decode_32k'),
        ('qwen2 decode_32k int8-KV', 'qwen2-72b__decode_32k_opt7_kv8'),
    ]
    out.append('| cell | compute s | memory s | collective s | args GB |')
    out.append('|---|---|---|---|---|')
    for label, tag in rows:
        c = _cell(tag)
        if c:
            out.append(f"| {label} | {c['compute_s']:.3f} | {c['mem_s']:.3f}"
                       f" | {c['coll_s']:.3f} | {c['args_gb']:.2f} |")
    text = '\n'.join(out) + '\n'
    with open('experiments/summary.md', 'w') as f:
        f.write(text)
    print(text)


if __name__ == '__main__':
    main()
