"""Build the EXPERIMENTS.md §Paper-results + §Perf tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.summarize
Writes experiments/summary.md (pasted into EXPERIMENTS.md).

``--diff-bench`` instead compares the serving telemetry time-series
(the ``timeseries`` blocks benchmarks/serving_load.py records into
BENCH_load.json / BENCH_chaos.json) against the previous committed
generation (``git show HEAD:<file>``): worst-window p99, peak queue
depth, and occupancy, flagging regressions past --tolerance.  Purely
informational on a noisy box — it prints REGRESSION markers but exits
zero unless --strict is given.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess

PAPER = 'experiments/paper'
DRY = 'experiments/dryrun/pod'


def _load(name):
    p = os.path.join(PAPER, name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def _cell(tagged):
    p = os.path.join(DRY, tagged + '.json')
    if not os.path.exists(p):
        return None
    with open(p) as f:
        r = json.load(f)
    coll = sum(r['collective_bytes'].values())
    return {'flops': r['flops_per_device'],
            'compute_s': r['flops_per_device'] / 197e12,
            'bytes': r['bytes_per_device'],
            'args_gb': r['memory']['argument_bytes'] / 1e9,
            'mem_s': (2 * r['bytes_per_device']
                      + r['memory']['argument_bytes']) / 819e9,
            'coll_s': coll / 50e9}


#: BENCH file -> scheduler-summary keys carrying a ``timeseries`` block
BENCH_TS = {
    'BENCH_load.json': ('static', 'compacting'),
    'BENCH_chaos.json': ('chaos_off', 'chaos_on', 'chaos_slo'),
    'BENCH_pipeline.json': ('single', 'pipeline', 'pipeline_static'),
}


def _ts_stats(block):
    """The three comparable scalars of one scheduler's timeseries block:
    (worst-window p99 s, peak queue depth, mean occupancy)."""
    ts = block.get('timeseries') or {}
    if not ts:
        return None
    p99 = (ts.get('worst_p99_window') or {}).get('p99_s')
    q = (ts.get('queue_depth') or {}).get('overall_peak')
    occ = [v for v in (ts.get('occupancy') or []) if v is not None]
    occ_mean = (sum(occ) / len(occ)) if occ else None
    return {'worst_p99_s': p99, 'peak_queue': q, 'mean_occupancy': occ_mean}


def diff_bench(tolerance=0.10, strict=False):
    """Diff current BENCH timeseries blocks vs the HEAD generation."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    n_reg = 0
    for fname, keys in BENCH_TS.items():
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            print(f'{fname}: not present, skipped')
            continue
        with open(path) as f:
            new = json.load(f)
        try:
            old = json.loads(subprocess.run(
                ['git', 'show', f'HEAD:{fname}'], cwd=root, check=True,
                capture_output=True, text=True).stdout)
        except (subprocess.CalledProcessError, json.JSONDecodeError):
            old = None
        print(f'{fname}:')
        for key in keys:
            cur = _ts_stats(new.get(key, {}))
            if cur is None:
                print(f'  {key}: no timeseries block in current run')
                continue
            prev = _ts_stats((old or {}).get(key, {}))
            if prev is None:
                print(f'  {key}: no previous-generation timeseries '
                      '(baseline recorded): '
                      + ' '.join(f'{k}={v}' for k, v in cur.items()))
                continue
            for metric, worse_is in (('worst_p99_s', 'higher'),
                                     ('peak_queue', 'higher'),
                                     ('mean_occupancy', 'lower')):
                a, b = prev[metric], cur[metric]
                if a is None or b is None or a == 0:
                    continue
                ratio = b / a
                regressed = (ratio > 1 + tolerance if worse_is == 'higher'
                             else ratio < 1 - tolerance)
                tag = '  REGRESSION' if regressed else ''
                n_reg += regressed
                print(f'  {key}.{metric}: {a:.6g} -> {b:.6g} '
                      f'({ratio:.2f}x){tag}')
    if n_reg:
        print(f'{n_reg} telemetry regression(s) past '
              f'{tolerance:.0%} tolerance')
        if strict:
            raise SystemExit(1)
    else:
        print('no telemetry regressions')


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--diff-bench', action='store_true',
                    help='diff BENCH_*.json timeseries vs the HEAD '
                         'generation instead of building summary.md')
    ap.add_argument('--tolerance', type=float, default=0.10)
    ap.add_argument('--strict', action='store_true',
                    help='--diff-bench exits non-zero on regression')
    args = ap.parse_args()
    if args.diff_bench:
        return diff_bench(tolerance=args.tolerance, strict=args.strict)
    out = []
    pw = _load('pairwise_order.json')
    if pw:
        out.append('### Pairwise order experiments (Figs. 6-11)\n')
        out.append('| pair | winner | score A->B | score B->A |')
        out.append('|---|---|---|---|')
        # registry-generic: every 2-letter result entry is a pair; pairs
        # decided structurally (one order inapplicable) carry no scores
        for key, r in pw.items():
            if not (isinstance(r, dict) and len(key) == 2
                    and r.get('winner')):
                continue
            a, b = key
            sa, sb = (r.get('score_' + a + b), r.get('score_' + b + a))
            fmt = lambda s: f'{s:.4f}' if s is not None else 'structural'
            out.append(f"| {a}{b} | **{r['winner']}** "
                       f"| {fmt(sa)} | {fmt(sb)} |")
        out.append(f"\ntopological order: **{pw['topological_order']}**"
                   f" (theoretical: {pw.get('theoretical_order', '?')}, "
                   f"dropped weak edges: {pw.get('dropped_edges')})\n")
    sl = _load('sequence_law.json')
    if sl:
        out.append('### Sequence law (Table 1)\n')
        budgets = list(next(iter(sl['table'].values()))['budget_crs'])
        out.append('| sequence | ' + ' | '.join(budgets) + ' |')
        out.append('|---' * (len(budgets) + 1) + '|')
        for seq, row in sl['table'].items():
            cells = [f'{v:.0f}x' if v else '-'
                     for v in row['budget_crs'].values()]
            out.append(f'| {seq} | ' + ' | '.join(cells) + ' |')
        out.append(f"\nbaseline accuracy {sl['baseline_acc']:.3f}\n")
    for name, title in [('chain_cnn_archs.json',
                         'Full chain on CNN families (Tables 2-4)'),
                        ('chain_lm_archs.json',
                         'Full chain transferred to LMs (beyond paper)')]:
        ca = _load(name)
        if ca:
            out.append(f'### {title}\n')
            out.append('| model | baseline acc | final acc | BitOpsCR | CR |')
            out.append('|---|---|---|---|---|')
            for model, d in ca.items():
                if not (isinstance(d, dict) and 'history' in d):
                    continue                       # meta keys ('sequence')
                h0, h1 = d['history'][0], d['history'][-1]
                out.append(f"| {model} | {h0['acc']:.3f} | {h1['acc']:.3f} "
                           f"| {h1['BitOpsCR']:.0f}x | {h1['CR']:.1f}x |")
            out.append('')
    rp = _load('repeat_compression.json')
    if rp:
        out.append('### Repeating compression (Fig. 14)\n')
        out.append('| variant | acc | BitOpsCR |')
        out.append('|---|---|---|')
        for k, v in rp.items():
            out.append(f"| {k} | {v['acc']:.3f} | {v['BitOpsCR']:.1f}x |")
        out.append('')

    out.append('### §Perf cells (final, consistent measurement)\n')
    rows = [
        ('mixtral train_4k baseline', 'mixtral-8x7b__train_4k_base3'),
        ('mixtral train_4k EP', 'mixtral-8x7b__train_4k'),
        ('deepseek train_4k baseline', 'deepseek-v3-671b__train_4k_base3'),
        ('deepseek train_4k EP(a2a)', 'deepseek-v3-671b__train_4k'),
        ('qwen2 decode_32k baseline', 'qwen2-72b__decode_32k'),
        ('qwen2 decode_32k int8-KV', 'qwen2-72b__decode_32k_opt7_kv8'),
    ]
    out.append('| cell | compute s | memory s | collective s | args GB |')
    out.append('|---|---|---|---|---|')
    for label, tag in rows:
        c = _cell(tag)
        if c:
            out.append(f"| {label} | {c['compute_s']:.3f} | {c['mem_s']:.3f}"
                       f" | {c['coll_s']:.3f} | {c['args_gb']:.2f} |")
    text = '\n'.join(out) + '\n'
    with open('experiments/summary.md', 'w') as f:
        f.write(text)
    print(text)


if __name__ == '__main__':
    main()
