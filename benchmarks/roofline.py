"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = 2 x HLO_buffer_bytes_per_device / HBM_bw   (r+w proxy)
    collective term = collective_bytes_per_device / ICI_link_bw
plus the dominant term, MODEL_FLOPS (6ND / 2ND), and the
MODEL_FLOPS / HLO_FLOPs usefulness ratio (catches remat/redundancy waste).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Methodology notes: the per-device numbers come from the
CPU-backend SPMD module (bf16 dots promoted to f32 -> bytes are an upper
bound; see launch/hlo_analysis.py docstring).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod]
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e
INT8_PEAK_FLOPS = 394e12     # v5e MXU: int8 doubles bf16 MACs/cycle


def int8_serving_roofline(plan_layers: dict) -> dict:
    """Roofline terms for one exported-CNN serving step on v5e, from a
    core/export.py LayerPlan's layer dicts (shapes include the batch).

    Two memory models per step: the PR-1 exported path (fp32 activations
    between layers + one abs-max read per layer) vs the int8-resident path
    (activations int8 in HBM, no abs-max pass).  This is what the
    requantize-epilogue work actually moves: the compute term is identical,
    the activation-traffic term shrinks ~4x — the fp32 HBM floor that
    bounded every previous speedup.

    The int8-resident term is dtype-accurate per layer: only the declared
    fp32 fallback layers (per-group depth > 1 grouped convs, none in this
    repo's families) pay 4 bytes/element on their outputs — depthwise
    layers run the int8 kernel (kernels/depthwise_conv.py) and move int8
    like everything else, with their share reported separately
    (``depthwise_bytes`` / ``depthwise_traffic_fraction``) instead of
    hiding in a fallback bucket.
    """
    # byte accounting is shared with the static analyzer's hlo-traffic
    # rule (repro/analysis/traffic.py) — one implementation, enforced at
    # export AND reported here
    from repro.analysis.traffic import boundary_bytes
    bb = boundary_bytes(plan_layers)
    elems_in, elems_out = bb['elems_in'], bb['elems_out']
    macs = sum(e['macs'] for e in plan_layers.values())
    batch = next(iter(plan_layers.values()))['in_shape'][0]
    flops = 2.0 * macs * batch
    t_c = flops / INT8_PEAK_FLOPS
    # fp32 path: read + write each layer boundary in fp32, plus the
    # dynamic abs-max pass re-reading every layer input
    t_m_fp32 = (4.0 * elems_in + 4.0 * elems_out + 4.0 * elems_in) / HBM_BW
    int8_bytes, dw_bytes = bb['int8_bytes'], bb['depthwise_bytes']
    t_m_int8 = int8_bytes / HBM_BW
    return {
        'compute_s': t_c,
        'memory_s_fp32_roundtrip': t_m_fp32,
        'memory_s_int8_resident': t_m_int8,
        'depthwise_bytes': dw_bytes,    # per step; shapes include the batch
        'depthwise_traffic_fraction': dw_bytes / max(int8_bytes, 1e-30),
        'bound_fp32': 'memory' if t_m_fp32 > t_c else 'compute',
        'bound_int8': 'memory' if t_m_int8 > t_c else 'compute',
        'traffic_reduction': t_m_fp32 / max(t_m_int8, 1e-30),
    }


def _prod(shape):
    n = 1
    for d in shape:
        n *= d
    return n

SHAPE_TOKENS = {'train_4k': (256, 4096, 'train'),
                'prefill_32k': (32, 32768, 'prefill'),
                'decode_32k': (128, 32768, 'decode'),
                'long_500k': (1, 524288, 'decode')}


def active_param_count(cfg):
    """N (active) from abstract shapes; MoE routed experts scaled by
    (top_k/ n_experts); embedding table excluded, unembed matmul included."""
    import jax
    from repro.models import build_model
    p = jax.eval_shape(lambda: build_model(cfg).init(jax.random.key(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(p)[0]:
        keys = [str(getattr(q, 'key', getattr(q, 'idx', q))) for q in path]
        n = 1
        for d in leaf.shape:
            n *= d
        if 'embed' in keys and 'exit' not in keys:
            if 'unembed' in keys:
                total += n
            continue                       # lookup, not matmul
        if 'moe' in keys and keys[-1] in ('wi', 'wg', 'wo'):
            E = cfg.n_experts
            n = n * cfg.top_k / E
        total += int(n)
    if cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model      # unembed matmul reuse
    return total


def model_flops(cfg, shape):
    B, S, kind = SHAPE_TOKENS[shape]
    N = active_param_count(cfg)
    if kind == 'train':
        return 6.0 * N * B * S
    if kind == 'prefill':
        return 2.0 * N * B * S
    return 2.0 * N * B                      # decode: one token per sequence


def analyze_cell(path, cfg_cache):
    from repro.configs import get_config
    with open(path) as f:
        r = json.load(f)
    cfg = cfg_cache.setdefault(r['arch'], get_config(r['arch']))
    chips = r['devices']
    t_c = r['flops_per_device'] / PEAK_FLOPS
    # memory term: intermediate buffers (written+read) + argument reads
    # (params + caches — the dtype-accurate memory_analysis numbers; this is
    # what the int8-serving iteration moves)
    t_m = (2.0 * r['bytes_per_device']
           + r['memory']['argument_bytes']) / HBM_BW
    coll = sum(r['collective_bytes'].values())
    t_x = coll / ICI_BW
    dom = max((('compute', t_c), ('memory', t_m), ('collective', t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, r['shape'])
    hlo_global = r['flops_per_device'] * chips
    mem = r['memory']
    hbm_need = mem['argument_bytes'] + mem['temp_bytes'] \
        + mem['output_bytes'] - mem.get('alias_bytes', 0)
    return {
        'arch': r['arch'], 'shape': r['shape'], 'mesh': r['mesh'],
        'chips': chips,
        'compute_s': t_c, 'memory_s': t_m, 'collective_s': t_x,
        'dominant': dom,
        'model_flops': mf, 'hlo_flops_global': hlo_global,
        'useful_ratio': mf / hlo_global if hlo_global else 0.0,
        'hbm_bytes_per_device': hbm_need,
        'fits_hbm': hbm_need <= HBM_PER_CHIP,
        'collective_by_kind': r['collective_bytes'],
        'compile_s': r.get('compile_s'),
    }


def main(mesh='pod', out_dir='experiments/dryrun'):
    d = os.path.join(out_dir, mesh)
    cfg_cache = {}
    rows = []
    for fn in sorted(os.listdir(d)):
        if not fn.endswith('.json') or '__' not in fn:
            continue
        shape_part = fn[:-5].split('__')[1]
        if shape_part not in SHAPE_TOKENS:          # skip tagged variants
            continue
        rows.append(analyze_cell(os.path.join(d, fn), cfg_cache))
    hdr = ('| arch | shape | compute s | memory s | collective s | dominant '
           '| useful (6ND/HLO) | HBM/dev GB | fits |')
    sep = '|' + '---|' * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['hbm_bytes_per_device'] / 1e9:.1f} "
            f"| {'y' if r['fits_hbm'] else 'N'} |")
    table = '\n'.join(lines)
    print(table)
    with open(f'experiments/roofline_{mesh}.md', 'w') as f:
        f.write(table + '\n')
    with open(f'experiments/roofline_{mesh}.json', 'w') as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--mesh', default='pod')
    args = ap.parse_args()
    main(args.mesh)
