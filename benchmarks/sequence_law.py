"""Paper Table 1: BitOpsCR of all distillation-started sequences.

Runs DPQE, DQPE, DPEQ, DQEP, DEPQ, DEQP from one shared baseline and
reports the max BitOpsCR under accuracy-loss budgets (<=0.2/0.6/1/2%),
validating that the sequence-law order DPQE dominates and near-law orders
(DQPE) come second.

Usage: PYTHONPATH=src python -m benchmarks.sequence_law [--steps 120]
"""
from __future__ import annotations

import argparse

from benchmarks import common

SEQUENCES = ('DPQE', 'DQPE', 'DPEQ', 'DQEP', 'DEPQ', 'DEQP')
BUDGETS = (0.01, 0.02, 0.05, 0.10, 0.20)


def run(steps=120, sequences=SEQUENCES):
    fam = common.make_family()
    tr = common.make_trainer(steps)
    base = common.baseline(fam, tr, pretrain_steps=steps * 3)
    base_acc = base.history[0]['acc']
    table = {}
    for seq in sequences:
        samples, st = common.chain_samples(fam, tr, base, seq,
                                           common.DEFAULT_HPS)
        row = {}
        for b in BUDGETS:
            ok = [cr for acc, cr in samples if acc >= base_acc - b]
            row[f'<={b * 100:.1f}%'] = max(ok) if ok else None
        table[seq] = {'budget_crs': row, 'samples': samples,
                      'history': st.history}
        print(seq, {k: (f'{v:.0f}x' if v else '-')
                    for k, v in row.items()})
    out = {'baseline_acc': base_acc, 'table': table}
    common.save_json('sequence_law.json', out)
    return out


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=120)
    args = ap.parse_args()
    run(args.steps)
