"""Serving-load benchmark: static batching vs early-exit slot compaction.

Drives the request-level serving runtime (repro/serving/) with a Poisson
arrival trace against an int8-resident exported CNN and A/Bs the two
schedulers on the SAME trace:

* ``static``     — full batches through the monolithic ``fn_exits``; the
  early-exit rule picks which head answers but every slot pays full depth.
* ``compacting`` — the stage-split plan: exited samples complete after
  their segment, survivors are compacted, freed slots backfill from the
  queue (ContinuousBatchScheduler).

Methodology on a noisy CI box: per-stage batch costs and the monolithic
batch cost are measured as **medians over --iters runs** at the fixed slot
geometry, then a simulated single-executor clock replays the trace on
those medians — the A/B cannot be corrupted by a concurrent load spike,
and the numbers are reproducible.  The data path is still executed for
real: every request's answer is checked bit-exact against the monolithic
model serving that request alone at the same slot geometry (the resident
export's bit-exactness contract; --oracle-all checks every request,
otherwise a sample).

Results go to BENCH_load.json (backend, batch geometry, median timings,
per-scheduler latency/throughput/occupancy).  ``--smoke`` is the CI
wiring: a tiny trace, asserts the scheduler drains the queue and answers
match the oracle, writes nothing unless --out is given.

    PYTHONPATH=src python benchmarks/serving_load.py [--slots 32] [--requests 512]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import median_us as _median_us  # noqa: E402  (shared convention)


def measure_stage_costs(model, x, iters=10):
    """Median per-segment batch cost (us) at the batch geometry of ``x``,
    feeding each segment the real carry of the previous one, plus the
    monolithic ``fn_exits`` cost on the same batch."""
    costs, carry = [], x
    for k in range(model.n_stages):
        costs.append(_median_us(model.stage_fns[k], model.params, carry,
                                iters=iters))
        if k < model.n_stages - 1:
            _, carry = model.run_stage(k, carry)
    mono = _median_us(model.fn_exits, model.params, x, iters=iters)
    return costs, mono


def poisson_trace(xs, rate, seed=0):
    """Requests over ``xs`` with exponential inter-arrival times (rate/s)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=xs.shape[0]))
    return [Request(i, xs[i], float(t[i])) for i in range(xs.shape[0])]


def check_oracle(model, completions, reqs, threshold, slots):
    """Every sampled request's answer must be bit-exact vs the monolithic
    model serving that request ALONE, padded to the same slot geometry."""
    from repro.serving import exit_decisions
    bad = []
    for r in reqs:
        xb = jnp.concatenate([r.x[None],
                              jnp.zeros((slots - 1,) + r.x.shape,
                                        r.x.dtype)])
        logits, exits = model.fn_exits(model.params, xb)
        stage, ans = exit_decisions(logits, exits, threshold)
        c = completions[r.rid]
        if c.exit_stage != int(stage[0]) or not np.array_equal(
                c.logits, ans[0]):
            bad.append(r.rid)
    return bad


def main():
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import calibrate_exit_threshold, export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.kernels.tiling import batch_slots
    from repro.serving import ContinuousBatchScheduler, StaticBatchScheduler

    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--slots', type=int, default=32)
    ap.add_argument('--requests', type=int, default=512)
    ap.add_argument('--iters', type=int, default=10)
    ap.add_argument('--rate', type=float, default=None,
                    help='arrival rate (req/s); default 2x the static '
                         'service capacity — heavy traffic, so each '
                         'scheduler completes at its own capacity and the '
                         'A/B measures service rate, not arrival rate')
    ap.add_argument('--threshold', type=float, default=None,
                    help='exit threshold; default calibrates to the batch-'
                         'median first-head confidence')
    ap.add_argument('--quantile', type=float, default=0.5,
                    help='calibration target: fraction exiting at head 1')
    ap.add_argument('--pallas', action='store_true',
                    help='force Pallas kernels (interpret mode on CPU)')
    ap.add_argument('--oracle-all', action='store_true',
                    help='oracle-check every request (default: 16 sampled)')
    ap.add_argument('--smoke', action='store_true',
                    help='tiny CI run: 24 requests, 8 slots, 2 iters, '
                         'asserts drain + bit-exact answers, no file '
                         'output unless --out is given')
    ap.add_argument('--out', default=None)
    args = ap.parse_args()
    if args.smoke:
        args.slots, args.requests, args.iters = 8, 24, 2
    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), 'BENCH_load.json')

    use_pallas = args.pallas or jax.default_backend() == 'tpu'
    slots = batch_slots(args.slots)
    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config].replace(w_bits=8, a_bits=8)
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params,
                                cfg.replace(exit_stages=()),
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)

    key = jax.random.key(7)
    xs = jax.random.normal(key, (args.requests, 32, 32, 3))
    calib = jax.random.normal(jax.random.fold_in(key, 1),
                              (slots, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=use_pallas, calibrate=calib)
    threshold = args.threshold
    if threshold is None:
        threshold = calibrate_exit_threshold(model, calib,
                                             quantile=args.quantile)
        print(f'calibrated exit threshold: {threshold:.4f} '
              f'(target exit quantile {args.quantile})')

    stage_costs_us, mono_us = measure_stage_costs(
        model, calib, iters=args.iters)

    # service capacities (req/s) from the median costs and the calibration
    # batch's exit mix: static pays the monolithic cost for every slot;
    # compacting pays segment k only for the fraction still alive there.
    from repro.serving import exit_decisions
    logits_c, exits_c = model.fn_exits(model.params, calib)
    stage_c, _ = exit_decisions(logits_c, exits_c, threshold)
    alive, cost_per_batch = 1.0, 0.0
    for k in range(model.n_stages):
        cost_per_batch += alive * stage_costs_us[k]
        if k < model.n_stages - 1:
            s = model.stage_exits[k]
            alive *= 1.0 - float(np.mean(stage_c == s))
    cap_static = slots / (mono_us * 1e-6)
    cap_compact = slots / (cost_per_batch * 1e-6)
    rate = args.rate or 2.0 * cap_static
    trace = poisson_trace(xs, rate, seed=0)

    static = StaticBatchScheduler(model, slots=slots, threshold=threshold,
                                  batch_cost=mono_us * 1e-6)
    s_comp, s_met = static.run_trace(trace)
    compacting = ContinuousBatchScheduler(
        model, slots=slots, threshold=threshold,
        stage_costs=[c * 1e-6 for c in stage_costs_us])
    c_comp, c_met = compacting.run_trace(trace)

    assert len(s_comp) == len(c_comp) == args.requests, \
        'scheduler failed to drain the queue'
    oracle_reqs = (trace if (args.smoke or args.oracle_all)
                   else trace[:: max(1, len(trace) // 16)])
    for name, comp in (('static', s_comp), ('compacting', c_comp)):
        bad = check_oracle(model, comp, oracle_reqs, threshold, slots)
        assert not bad, f'{name}: requests {bad[:8]} diverge from oracle'
    agree = all(s_comp[r.rid].exit_stage == c_comp[r.rid].exit_stage
                and np.array_equal(s_comp[r.rid].logits,
                                   c_comp[r.rid].logits) for r in trace)
    assert agree, 'static and compacting schedulers disagree on answers'

    s_sum, c_sum = s_met.summary(), c_met.summary()
    results = {
        'backend': jax.default_backend(),
        'int8_path': 'pallas' if use_pallas else 'jnp-ref',
        'config': cfg.name,
        'batch_geometry': {'slots_requested': args.slots,
                           'slots_padded': slots,
                           'image': [32, 32, 3]},
        'n_requests': args.requests,
        'arrival_rate_rps': round(rate, 3),
        'exit_threshold': round(threshold, 6),
        'timing': {'iters': args.iters, 'reduction': 'median',
                   'stage_costs_us': [round(c, 1) for c in stage_costs_us],
                   'monolithic_us': round(mono_us, 1)},
        'capacity_static_rps': round(cap_static, 3),
        'capacity_compacting_rps': round(cap_compact, 3),
        'static': s_sum,
        'compacting': c_sum,
        'compaction_throughput_x': round(
            c_sum['throughput_rps'] / max(s_sum['throughput_rps'], 1e-9), 3),
        'compaction_p99_x': round(
            s_sum['p99_latency_s'] / max(c_sum['p99_latency_s'], 1e-9), 3),
    }
    print(f"{cfg.name} slots={slots} rate={rate:.0f}/s "
          f"exit_fraction={c_sum['exit_fraction']:.2f}")
    print(f"  static:     {s_sum['throughput_rps']:.0f} req/s  "
          f"p50={s_sum['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={s_sum['p99_latency_s'] * 1e3:.2f}ms")
    print(f"  compacting: {c_sum['throughput_rps']:.0f} req/s  "
          f"p50={c_sum['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={c_sum['p99_latency_s'] * 1e3:.2f}ms "
          f"occupancy={c_sum['batch_occupancy']}")
    print(f"  compaction: {results['compaction_throughput_x']:.2f}x "
          f"throughput, {results['compaction_p99_x']:.2f}x p99")
    if args.smoke:
        print('smoke OK: queue drained, answers bit-exact vs oracle')

    if out:
        with open(out, 'w') as f:
            json.dump(results, f, indent=1)
        print(f'wrote {out}')


if __name__ == '__main__':
    main()
