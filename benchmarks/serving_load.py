"""Serving-load benchmark: static batching vs early-exit slot compaction.

Drives the request-level serving runtime (repro/serving/) with a Poisson
arrival trace against an int8-resident exported CNN and A/Bs the two
schedulers on the SAME trace:

* ``static``     — full batches through the monolithic ``fn_exits``; the
  early-exit rule picks which head answers but every slot pays full depth.
* ``compacting`` — the stage-split plan: exited samples complete after
  their segment, survivors are compacted, freed slots backfill from the
  queue (ContinuousBatchScheduler).

Methodology on a noisy CI box: per-stage batch costs and the monolithic
batch cost are measured as **medians over --iters runs** at the fixed slot
geometry, then a simulated single-executor clock replays the trace on
those medians — the A/B cannot be corrupted by a concurrent load spike,
and the numbers are reproducible.  The data path is still executed for
real: every request's answer is checked bit-exact against the monolithic
model serving that request alone at the same slot geometry (the resident
export's bit-exactness contract; --oracle-all checks every request,
otherwise a sample).

Results go to BENCH_load.json (backend, batch geometry, median timings,
per-scheduler latency/throughput/occupancy, plus a windowed ``timeseries``
block per scheduler — queue depth, rolling p99, occupancy over the run —
that ``benchmarks/summarize.py --diff-bench`` compares across
generations).  ``--smoke`` is the CI wiring: a tiny trace, asserts the
scheduler drains the queue and answers match the oracle, writes nothing
unless --out is given.

``--trace out.json`` records the run (the compacting scheduler, or the
chaos-on pool run under --chaos) as Chrome-trace JSON, validates its span
invariants strictly (``repro.obs.check_trace`` — including a round-trip
through the written file), and prints a one-line telemetry digest.

``--chaos`` switches to the resilience benchmark over the replica pool
(repro/serving/replica.py): the model is served from a persisted chain
checkpoint through the registry, a bursty oversubscribed trace drives an
elastic pool, and a seeded :class:`ChaosPlan` injects a mid-batch replica
kill (failover restores a replacement through
``ModelRegistry.restore`` — the chain-checkpoint path) plus a straggler
slowdown (flagged and de-prioritized by the EWMA monitor).  Three runs on
the same trace: chaos-off baseline, chaos-on (asserted zero-loss and
bit-exact vs the baseline AND the request-alone oracle), and chaos-on
with deadlines (asserted never-late: every deadline request is on time,
degraded through an exit head, or rejected at admission).  Results go to
BENCH_chaos.json (availability, SLO attainment, degraded-exit mix,
failover count, p99 chaos-on vs chaos-off).

    PYTHONPATH=src python benchmarks/serving_load.py [--slots 32] [--requests 512]
    PYTHONPATH=src python benchmarks/serving_load.py --chaos
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import median_us as _median_us  # noqa: E402  (shared convention)


def measure_stage_costs(model, x, iters=10):
    """Median per-segment batch cost (us) at the batch geometry of ``x``,
    feeding each segment the real carry of the previous one, plus the
    monolithic ``fn_exits`` cost on the same batch."""
    costs, carry = [], x
    for k in range(model.n_stages):
        costs.append(_median_us(model.stage_fns[k], model.params, carry,
                                iters=iters))
        if k < model.n_stages - 1:
            _, carry = model.run_stage(k, carry)
    mono = _median_us(model.fn_exits, model.params, x, iters=iters)
    return costs, mono


def poisson_trace(xs, rate, seed=0):
    """Requests over ``xs`` with exponential inter-arrival times (rate/s)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=xs.shape[0]))
    return [Request(i, xs[i], float(t[i])) for i in range(xs.shape[0])]


def burst_trace(xs, rate, seed=0, n_bursts=2, burst=8):
    """Poisson arrivals with injected spikes: ``n_bursts`` groups of
    ``burst`` consecutive requests arrive at the same instant (the chaos
    benchmark's arrival-burst element)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=xs.shape[0])
    n = xs.shape[0]
    for b in range(n_bursts):
        s = int((b + 1) * n / (n_bursts + 1))
        gaps[s:min(s + burst, n)] = 0.0
    t = np.cumsum(gaps)
    return [Request(i, xs[i], float(t[i])) for i in range(n)]


def check_oracle(model, completions, reqs, threshold, slots):
    """Every sampled request's answer must be bit-exact vs the monolithic
    model serving that request ALONE, padded to the same slot geometry."""
    from repro.serving import exit_decisions
    bad = []
    for r in reqs:
        xb = jnp.concatenate([r.x[None],
                              jnp.zeros((slots - 1,) + r.x.shape,
                                        r.x.dtype)])
        logits, exits = model.fn_exits(model.params, xb)
        stage, ans = exit_decisions(logits, exits, threshold)
        c = completions[r.rid]
        if c.exit_stage != int(stage[0]) or not np.array_equal(
                c.logits, ans[0]):
            bad.append(r.rid)
    return bad


def validate_and_write_trace(tracer, completions, path, *,
                             require_failover=False):
    """Strict invariant check on the recorded spans, write the Chrome
    trace, and re-validate what was actually written (round-trip through
    the exporter/parser).  ``require_failover`` additionally asserts the
    chaos story is visible: a killed ``stage.exec`` on the dead replica's
    track and a ``failover.restore`` span on the replacement's."""
    from repro.obs import check_trace, load_chrome_trace
    check_trace(tracer, completions, strict=True)
    if require_failover:
        killed = [s for s in tracer.spans
                  if s.name == 'stage.exec' and s.args.get('killed')]
        restores = [s for s in tracer.spans
                    if s.name == 'failover.restore']
        assert killed, 'chaos trace has no killed stage.exec span'
        assert restores, 'chaos trace has no failover.restore span'
        assert all(s.track.startswith('replica') for s in killed + restores)
    tracer.write(path)
    check_trace(load_chrome_trace(path), completions, strict=True)
    print(f'  trace: {len(tracer.spans)} spans -> {path} '
          f'(validated, open at https://ui.perfetto.dev)')


def run_chaos(args, fam, cfg, params, xs, calib, threshold, stage_costs_us,
              slots, use_pallas, out):
    """The --chaos path: three replica-pool runs on one bursty trace.

    A: chaos off (the undisturbed baseline).  B: seeded kill + straggler
    slowdown — must drain with zero lost requests, every answer bit-exact
    vs A and vs the request-alone oracle, failover restoring through the
    registry's chain checkpoint.  C: B plus per-request deadlines — the
    SLO layer must keep every admitted request on time (degrading through
    the exit heads when the budget runs short), never silently late.
    """
    import tempfile

    from repro.checkpoint import save_chain_state
    from repro.core.passes import ChainState
    from repro.serving import (ChaosPlan, ModelRegistry,
                               ReplicaPoolScheduler, Request, SLOPolicy)

    # serve from a persisted chain checkpoint so failover exercises the
    # real restore path (registry -> chain_io -> re-export)
    ckpt = tempfile.mkdtemp(prefix='chaos_ckpt_')
    st = ChainState(family=fam, cfg=cfg, params=params,
                    key=jax.random.key(7), exit_threshold=threshold)
    save_chain_state(ckpt, st, step=0)
    reg = ModelRegistry()
    model = reg.load('cnn', ckpt, fam, use_pallas=use_pallas,
                     calibrate=calib)

    costs = [c * 1e-6 for c in stage_costs_us]
    # oversubscribe the MAXED-OUT pool 2x: replicas stay busy for the
    # whole trace (the seeded kill is guaranteed to land mid-batch) and
    # elastic scaling is driven to its ceiling
    rate = args.rate or 2.0 * args.max_replicas * slots / sum(costs)
    trace = burst_trace(xs, rate, seed=0, burst=max(slots, 8))
    pool_kw = dict(slots=slots, threshold=threshold, stage_costs=costs,
                   replicas=args.replicas, min_replicas=args.replicas,
                   max_replicas=args.max_replicas,
                   restore=lambda: reg.restore('cnn'),
                   restore_delay=costs[0])

    base_comp, base_met = ReplicaPoolScheduler(
        model, **pool_kw).run_trace(trace)
    assert len(base_comp) == len(trace), 'baseline run lost requests'

    # chaos times are fractions of the baseline run's MEASURED makespan,
    # not the arrival horizon — on an oversubscribed trace most serving
    # happens in the drain phase, and an a-priori work estimate misses
    # how much early exits shrink it (a kill seeded past the true
    # makespan would never fire)
    makespan = max(c.t_done for c in base_comp.values())
    plan = ChaosPlan.seeded(args.chaos_seed, args.replicas, makespan)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    chaos_comp, chaos_met = ReplicaPoolScheduler(
        model, chaos=plan, tracer=tracer, **pool_kw).run_trace(trace)
    b_sum, c_sum = base_met.summary(), chaos_met.summary()
    res = c_sum['resilience']
    assert len(chaos_comp) == len(trace), 'chaos run lost requests'
    assert c_sum['availability'] == 1.0, 'chaos run rejected requests'
    assert res['kills'] >= 1 and res['failovers'] >= 1, 'no kill fired'
    assert any(i.get('mid_batch') for k, _, i in chaos_met.events
               if k == 'kill'), 'kill landed on an idle replica'
    assert res['straggler_flags'] >= 1, 'slowdown never flagged'
    for r in trace:
        b, c = base_comp[r.rid], chaos_comp[r.rid]
        assert c.exit_stage == b.exit_stage and np.array_equal(
            c.logits, b.logits), f'request {r.rid} diverged under chaos'
    oracle_reqs = (trace if (args.smoke or args.oracle_all)
                   else trace[:: max(1, len(trace) // 16)])
    bad = check_oracle(model, chaos_comp, oracle_reqs, threshold, slots)
    assert not bad, f'chaos: requests {bad[:8]} diverge from oracle'

    full_cost = sum(costs)
    rng = np.random.default_rng(args.chaos_seed + 1)
    budgets = full_cost * rng.uniform(0.5, 6.0, size=len(trace))
    slo_trace = [Request(r.rid, r.x, r.t_arrival,
                         deadline=r.t_arrival + float(budgets[i]))
                 for i, r in enumerate(trace)]
    slo_comp, slo_met = ReplicaPoolScheduler(
        model, chaos=plan, slo=SLOPolicy(), **pool_kw).run_trace(slo_trace)
    s_sum = slo_met.summary()
    b_sum['timeseries'] = base_met.timeseries()
    c_sum['timeseries'] = chaos_met.timeseries()
    s_sum['timeseries'] = slo_met.timeseries()
    assert s_sum['slo']['n_late'] == 0, 'never-late contract violated'
    for c in slo_comp.values():
        if not c.degraded:
            b = base_comp[c.rid]
            assert c.exit_stage == b.exit_stage and np.array_equal(
                c.logits, b.logits), \
                f'request {c.rid} diverged under chaos+SLO'

    results = {
        'backend': jax.default_backend(),
        'int8_path': 'pallas' if use_pallas else 'jnp-ref',
        'config': cfg.name,
        'batch_geometry': {'slots': slots, 'image': [32, 32, 3]},
        'n_requests': len(trace),
        'arrival_rate_rps': round(rate, 3),
        'exit_threshold': round(threshold, 6),
        'pool': {'replicas': args.replicas, 'min_replicas': args.replicas,
                 'max_replicas': args.max_replicas},
        'timing': {'iters': args.iters, 'reduction': 'median',
                   'stage_costs_us': [round(c, 1) for c in stage_costs_us]},
        'chaos_plan': {'seed': args.chaos_seed,
                       'kills': [list(k) for k in plan.kills],
                       'slowdowns': [list(s) for s in plan.slowdowns]},
        'deadline_budget_x_full_depth': [0.5, 6.0],
        'chaos_off': b_sum,
        'chaos_on': c_sum,
        'chaos_slo': s_sum,
        'availability': c_sum['availability'],
        'slo_attainment': s_sum['slo']['attainment'],
        'degraded_exit_mix': s_sum['degraded_exit_mix'],
        'failovers': res['failovers'],
        'p99_chaos_off_s': b_sum['p99_latency_s'],
        'p99_chaos_on_s': c_sum['p99_latency_s'],
        'chaos_p99_x': round(c_sum['p99_latency_s']
                             / max(b_sum['p99_latency_s'], 1e-12), 3),
    }
    print(f"{cfg.name} slots={slots} rate={rate:.0f}/s pool="
          f"{args.replicas}..{args.max_replicas} replicas")
    print(f"  chaos off: p99={b_sum['p99_latency_s'] * 1e3:.2f}ms "
          f"throughput={b_sum['throughput_rps']:.0f} req/s")
    print(f"  chaos on:  p99={c_sum['p99_latency_s'] * 1e3:.2f}ms "
          f"({results['chaos_p99_x']:.2f}x) availability="
          f"{c_sum['availability']:.4f} kills={res['kills']} "
          f"failovers={res['failovers']} "
          f"straggler_flags={res['straggler_flags']} "
          f"peak_replicas={res['peak_replicas']}")
    print(f"  chaos+SLO: attainment={s_sum['slo']['attainment']:.4f} "
          f"on_time={s_sum['slo']['n_on_time']} "
          f"late={s_sum['slo']['n_late']} "
          f"degraded={s_sum['n_degraded']} "
          f"rejected={s_sum['n_rejected']} "
          f"degraded_mix={s_sum['degraded_exit_mix']}")
    print('  ' + chaos_met.telemetry_digest())
    if tracer is not None:
        validate_and_write_trace(tracer, chaos_comp, args.trace,
                                 require_failover=True)
    if args.smoke:
        print('chaos smoke OK: zero lost, bit-exact under kill+straggler, '
              'no late completion')
    if out:
        with open(out, 'w') as f:
            json.dump(results, f, indent=1)
        print(f'wrote {out}')


def main():
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import calibrate_exit_threshold, export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.kernels.tiling import batch_slots
    from repro.serving import ContinuousBatchScheduler, StaticBatchScheduler

    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--slots', type=int, default=32)
    ap.add_argument('--requests', type=int, default=512)
    ap.add_argument('--iters', type=int, default=10)
    ap.add_argument('--rate', type=float, default=None,
                    help='arrival rate (req/s); default 2x the static '
                         'service capacity — heavy traffic, so each '
                         'scheduler completes at its own capacity and the '
                         'A/B measures service rate, not arrival rate')
    ap.add_argument('--threshold', type=float, default=None,
                    help='exit threshold; default calibrates to the batch-'
                         'median first-head confidence')
    ap.add_argument('--quantile', type=float, default=0.5,
                    help='calibration target: fraction exiting at head 1')
    ap.add_argument('--pallas', action='store_true',
                    help='force Pallas kernels (interpret mode on CPU)')
    ap.add_argument('--oracle-all', action='store_true',
                    help='oracle-check every request (default: 16 sampled)')
    ap.add_argument('--smoke', action='store_true',
                    help='tiny CI run: 24 requests, 8 slots, 2 iters, '
                         'asserts drain + bit-exact answers, no file '
                         'output unless --out is given')
    ap.add_argument('--chaos', action='store_true',
                    help='resilience benchmark: replica pool under seeded '
                         'kill + straggler + bursts (BENCH_chaos.json)')
    ap.add_argument('--chaos-seed', type=int, default=0)
    ap.add_argument('--replicas', type=int, default=2,
                    help='--chaos: initial replica count')
    ap.add_argument('--max-replicas', type=int, default=4,
                    help='--chaos: elastic scaling ceiling')
    ap.add_argument('--trace', default=None, metavar='OUT.json',
                    help='record the run (compacting scheduler, or the '
                         'chaos-on pool run under --chaos) as Chrome-trace '
                         'JSON, strictly validated via repro.obs')
    ap.add_argument('--out', default=None)
    args = ap.parse_args()
    if args.smoke:
        args.slots, args.requests, args.iters = 8, 24, 2
        if args.chaos:
            args.requests = 32        # enough in-flight work for the kill
    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))),
            'BENCH_chaos.json' if args.chaos else 'BENCH_load.json')

    use_pallas = args.pallas or jax.default_backend() == 'tpu'
    slots = batch_slots(args.slots)
    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config].replace(w_bits=8, a_bits=8)
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params,
                                cfg.replace(exit_stages=()),
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)

    key = jax.random.key(7)
    xs = jax.random.normal(key, (args.requests, 32, 32, 3))
    calib = jax.random.normal(jax.random.fold_in(key, 1),
                              (slots, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=use_pallas, calibrate=calib)
    threshold = args.threshold
    if threshold is None:
        threshold = calibrate_exit_threshold(model, calib,
                                             quantile=args.quantile)
        print(f'calibrated exit threshold: {threshold:.4f} '
              f'(target exit quantile {args.quantile})')

    stage_costs_us, mono_us = measure_stage_costs(
        model, calib, iters=args.iters)

    if args.chaos:
        return run_chaos(args, fam, cfg, params, xs, calib, threshold,
                         stage_costs_us, slots, use_pallas, out)

    # service capacities (req/s) from the median costs and the calibration
    # batch's exit mix: static pays the monolithic cost for every slot;
    # compacting pays segment k only for the fraction still alive there.
    from repro.serving import exit_decisions
    logits_c, exits_c = model.fn_exits(model.params, calib)
    stage_c, _ = exit_decisions(logits_c, exits_c, threshold)
    alive, cost_per_batch = 1.0, 0.0
    for k in range(model.n_stages):
        cost_per_batch += alive * stage_costs_us[k]
        if k < model.n_stages - 1:
            s = model.stage_exits[k]
            alive *= 1.0 - float(np.mean(stage_c == s))
    cap_static = slots / (mono_us * 1e-6)
    cap_compact = slots / (cost_per_batch * 1e-6)
    rate = args.rate or 2.0 * cap_static
    trace = poisson_trace(xs, rate, seed=0)

    static = StaticBatchScheduler(model, slots=slots, threshold=threshold,
                                  batch_cost=mono_us * 1e-6)
    s_comp, s_met = static.run_trace(trace)
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    compacting = ContinuousBatchScheduler(
        model, slots=slots, threshold=threshold,
        stage_costs=[c * 1e-6 for c in stage_costs_us], tracer=tracer)
    c_comp, c_met = compacting.run_trace(trace)

    assert len(s_comp) == len(c_comp) == args.requests, \
        'scheduler failed to drain the queue'
    oracle_reqs = (trace if (args.smoke or args.oracle_all)
                   else trace[:: max(1, len(trace) // 16)])
    for name, comp in (('static', s_comp), ('compacting', c_comp)):
        bad = check_oracle(model, comp, oracle_reqs, threshold, slots)
        assert not bad, f'{name}: requests {bad[:8]} diverge from oracle'
    agree = all(s_comp[r.rid].exit_stage == c_comp[r.rid].exit_stage
                and np.array_equal(s_comp[r.rid].logits,
                                   c_comp[r.rid].logits) for r in trace)
    assert agree, 'static and compacting schedulers disagree on answers'

    s_sum, c_sum = s_met.summary(), c_met.summary()
    s_sum['timeseries'] = s_met.timeseries()
    c_sum['timeseries'] = c_met.timeseries()
    results = {
        'backend': jax.default_backend(),
        'int8_path': 'pallas' if use_pallas else 'jnp-ref',
        'config': cfg.name,
        'batch_geometry': {'slots_requested': args.slots,
                           'slots_padded': slots,
                           'image': [32, 32, 3]},
        'n_requests': args.requests,
        'arrival_rate_rps': round(rate, 3),
        'exit_threshold': round(threshold, 6),
        'timing': {'iters': args.iters, 'reduction': 'median',
                   'stage_costs_us': [round(c, 1) for c in stage_costs_us],
                   'monolithic_us': round(mono_us, 1)},
        'capacity_static_rps': round(cap_static, 3),
        'capacity_compacting_rps': round(cap_compact, 3),
        'static': s_sum,
        'compacting': c_sum,
        'compaction_throughput_x': round(
            c_sum['throughput_rps'] / max(s_sum['throughput_rps'], 1e-9), 3),
        'compaction_p99_x': round(
            s_sum['p99_latency_s'] / max(c_sum['p99_latency_s'], 1e-9), 3),
    }
    print(f"{cfg.name} slots={slots} rate={rate:.0f}/s "
          f"exit_fraction={c_sum['exit_fraction']:.2f}")
    print(f"  static:     {s_sum['throughput_rps']:.0f} req/s  "
          f"p50={s_sum['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={s_sum['p99_latency_s'] * 1e3:.2f}ms")
    print(f"  compacting: {c_sum['throughput_rps']:.0f} req/s  "
          f"p50={c_sum['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={c_sum['p99_latency_s'] * 1e3:.2f}ms "
          f"occupancy={c_sum['batch_occupancy']}")
    print(f"  compaction: {results['compaction_throughput_x']:.2f}x "
          f"throughput, {results['compaction_p99_x']:.2f}x p99")
    print('  ' + c_met.telemetry_digest())
    if tracer is not None:
        validate_and_write_trace(tracer, c_comp, args.trace)
    if args.smoke:
        print('smoke OK: queue drained, answers bit-exact vs oracle')

    if out:
        with open(out, 'w') as f:
            json.dump(results, f, indent=1)
        print(f'wrote {out}')


if __name__ == '__main__':
    main()
