"""Serving benchmark: fake-quant fp32 forward vs the exported int8 path.

The chain's Q pass is only *analytically* cheaper until export: the QAT
forward runs fp32 convs and recomputes per-channel weight abs-max scales on
every call.  This benchmark times, per CNN config:

* ``fakequant_fp32`` — the QAT forward (per-call weight scale recompute)
* ``exported_int8``  — core/export.py serving fn (static weight scales,
  int8 conv/matmul; jnp int8 path on CPU, Pallas kernels on TPU)
* ``exported_int8_early_exit`` — batched early-exit serving (resnet8)

Results go to BENCH_serving.json at the repo root.

    PYTHONPATH=src python benchmarks/serving_int8.py [--batch 64] [--pallas]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                                   VGG8_CIFAR)
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.models.cnn import cnn_forward, init_cnn

    ap = argparse.ArgumentParser()
    ap.add_argument('--batch', type=int, default=64)
    ap.add_argument('--iters', type=int, default=10)
    ap.add_argument('--pallas', action='store_true',
                    help='force the Pallas kernels (interpret mode on CPU '
                         '— correctness timing only, very slow)')
    ap.add_argument('--out', default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        'BENCH_serving.json'))
    args = ap.parse_args()

    # Same auto-dispatch rule export_cnn applies for use_pallas=None, made
    # explicit here so the recorded label always matches the timed path.
    # On CPU the jnp reference path uses an int8 einsum for dense layers
    # but dequantizes convs to fp32 lax.conv (no int8 conv units) — CPU
    # "speedup" isolates the static-scale win, not int8 compute.
    use_pallas = args.pallas or jax.default_backend() == 'tpu'
    x = jax.random.normal(jax.random.key(0), (args.batch, 32, 32, 3))
    fam = CNNFamily(SyntheticImages())
    results = {'backend': jax.default_backend(),
               'batch': args.batch,
               'int8_path': 'pallas' if use_pallas else 'jnp-ref',
               'configs': {}}

    for base in (RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR):
        cfg = base.replace(w_bits=8, a_bits=8)
        params = init_cnn(jax.random.key(0), cfg)
        if base is RESNET8_CIFAR:      # early-exit serving entry
            params, cfg = fam.add_exits(jax.random.key(1), params,
                                        cfg.replace(exit_stages=()), (1,))
            cfg = cfg.replace(w_bits=8, a_bits=8)

        fake = jax.jit(lambda p, x, c=cfg: cnn_forward(p, c, x))
        us_fake = _time(fake, params, x, iters=args.iters)

        m = export_cnn(params, cfg, use_pallas=use_pallas)
        us_int8 = _time(m.fn, m.params, x, iters=args.iters)

        entry = {'fakequant_fp32_us': round(us_fake, 1),
                 'exported_int8_us': round(us_int8, 1),
                 'speedup': round(us_fake / us_int8, 3)}
        if cfg.exit_stages:
            from repro.core.export import early_exit_batch

            @jax.jit
            def ee(p, x):            # the full deployed early-exit path:
                logits, exits = m.fn_exits(p, x)   # forward + exit heads
                return early_exit_batch(logits, exits, 0.85)   # + selection

            us_ee = _time(ee, m.params, x, iters=args.iters)
            _, stage = ee(m.params, x)
            entry['exported_int8_early_exit_us'] = round(us_ee, 1)
            entry['exit_fraction'] = round(
                float(jnp.mean(stage >= 0)), 3)
        results['configs'][cfg.name] = entry
        print(f'{cfg.name}: fakequant_fp32={us_fake:.1f}us '
              f'exported_int8={us_int8:.1f}us '
              f'speedup={us_fake / us_int8:.2f}x')

    with open(args.out, 'w') as f:
        json.dump(results, f, indent=1)
    print(f'wrote {args.out}')


if __name__ == '__main__':
    main()
