"""Serving benchmark: fake-quant fp32 forward vs the exported int8 paths.

The chain's Q pass is only *analytically* cheaper until export: the QAT
forward runs fp32 convs and recomputes per-channel weight abs-max scales on
every call.  This benchmark times, per CNN config:

* ``fakequant_fp32``  — the QAT forward (per-call weight scale recompute)
* ``exported_int8``   — the PR-1 dynamic-scale export (static weight
  scales, one activation abs-max per layer, fp32 between layers)
* ``int8_resident``   — the layer-plan export (core/export.py
  ``calibrate=...``): static activation scales, requantize epilogues,
  int8 activations between layers, folded constants on the CPU backend
* ``exported_int8_early_exit`` — batched early-exit serving (resnet8);
  if no sample exits at the configured threshold, the benchmark warns and
  recalibrates the threshold to the batch's median exit confidence so the
  E pass is actually exercised
* ``lowrank_fused`` / ``lowrank_two_launch`` — the factored ('L' pass)
  model served with the one-launch fused kernel (forced via
  ``select_kernels='fused'``) vs the chained pair (``fuse_lowrank=False``);
  the measured ``winner`` and the per-layer choice the default cost model
  would make (``model_selection``) are both recorded, so the A/B shows
  whether export-time selection ships the faster lowering.  The two
  lowerings are identical on the CPU jnp backend — the A/B becomes real
  on TPU, where the launch counts differ; tests pin them.

``--smoke`` additionally asserts the zero-fp32 contract: mobilenet's plan
must report ``fallback_mac_fraction == 0`` (depthwise serves on the int8
kernel), and a ``select_kernels='measure'`` export must never record a
choice that its own measurements say is slower (selection consistency).

``--breakdown`` adds a per-layer table (im2col/patch-materialization cost
vs kernel cost — the resnet8 int8 regression of PR 1 lived there) and the
v5e roofline estimate for the fp32-roundtrip vs int8-resident HBM traffic.
``--smoke`` runs a tiny batch with 2 iterations and writes nothing unless
``--out`` is given (the scripts/ci.sh wiring).

Timings are medians over ``--iters`` runs (CI boxes are noisy).

Results go to BENCH_serving.json at the repo root.

    PYTHONPATH=src python benchmarks/serving_int8.py [--batch 64] [--pallas]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import median_us as _time  # noqa: E402  (shared convention)


def _early_exit_entry(m, x, iters, threshold=0.85):
    """Time batched early-exit serving; calibrate the threshold when the
    configured one never fires (ChainState.exit_threshold must actually be
    exercised at batch serving, not silently bypass every sample).

    The model is NOT mutated: the benchmark threshold is passed into the
    serving call, and a recalibrated operating point is *returned* in the
    entry (``exit_threshold_calibrated``).  A caller holding the chain
    should persist that value to ``ChainState.exit_threshold`` (which
    ``export_chain`` threads into future exports) — a benchmark has no
    business rewriting a live ServingModel behind its owner's back."""
    from repro.core.export import calibrate_exit_threshold, early_exit_batch

    def ee(p, x, thr):
        logits, exits = m.fn_exits(p, x)
        return early_exit_batch(logits, exits, thr)

    jee = jax.jit(ee, static_argnums=(2,))
    us = _time(jee, m.params, x, threshold, iters=iters)
    _, stage = jee(m.params, x, threshold)
    frac = float(jnp.mean(stage >= 0))
    entry = {'exported_int8_early_exit_us': round(us, 1),
             'exit_threshold': threshold,
             'exit_fraction': round(frac, 3)}
    if frac == 0.0:
        # the threshold never fires on this input distribution: recalibrate
        # to the median confidence of the earliest exit head and re-run
        thr = calibrate_exit_threshold(m, x)
        print(f'  WARNING: no sample exited at threshold {threshold:.2f}; '
              f'recalibrated to batch-median confidence {thr:.3f}')
        us2 = _time(jee, m.params, x, thr, iters=iters)
        _, stage2 = jee(m.params, x, thr)
        entry.update(
            exit_threshold_calibrated=round(thr, 4),
            exit_fraction_calibrated=round(float(jnp.mean(stage2 >= 0)), 3),
            exported_int8_early_exit_calibrated_us=round(us2, 1))
    return entry


def _breakdown(m, x, iters, use_pallas):
    """Per-layer costs from the layer plan: patch materialization (im2col)
    vs the int8 kernel, over the exact serving shapes and the same
    lowering (Pallas vs jnp reference) as the timed serving fn."""
    from repro.kernels import ops
    from repro.kernels.quant_conv import im2col_nhwc
    rows = []
    for name, e in m.plan.layers.items():
        if e['kind'] != 'conv' or e['factored']:
            continue
        cin, cout = e['in_shape'][-1], e['out_shape'][-1]
        kh, kw = e['kernel']
        x_q = jnp.zeros(e['in_shape'], jnp.int8)
        if e['fallback']:
            # fallback layers never materialize im2col patches (they serve
            # via lax.conv directly on NHWC) — no costs to attribute
            # beyond the declared fp32 conv itself
            us_i = us_k = None
        elif e.get('depthwise'):
            # depthwise is the direct (non-im2col) int8 kernel: no patch
            # cost at all, just the per-channel VPU kernel
            sw = jnp.ones((cout,), jnp.float32)
            us_i = 0.0
            conv = jax.jit(lambda v, s=e['stride'], sx=e['sx'], sw=sw:
                           ops.depthwise_conv_static(
                               v, jnp.zeros((kh, kw, 1, cout), jnp.int8),
                               sw, sx=sx, stride=s, use_pallas=use_pallas))
            us_k = round(_time(conv, x_q, iters=iters), 1)
        else:
            w_q = jnp.zeros((kh, kw, cin, cout), jnp.int8)
            sw = jnp.ones((cout,), jnp.float32)
            im2col = jax.jit(lambda v, k=(kh, kw), s=e['stride']:
                             im2col_nhwc(v, k[0], k[1], s)[0])
            us_i = round(_time(im2col, x_q, iters=iters), 1)
            conv = jax.jit(lambda v, wq=w_q, s=e['stride'], sx=e['sx']:
                           ops.quant_conv_static(v, wq, sw, sx=sx, stride=s,
                                                 use_pallas=use_pallas))
            us_k = round(_time(conv, x_q, iters=iters), 1)
        rows.append({'layer': name, 'in_shape': list(e['in_shape']),
                     'macs': e['macs'], 'im2col_us': us_i,
                     'kernel_us': us_k, 'fallback': e['fallback'],
                     'depthwise': bool(e.get('depthwise'))})
        print(f"  {name:14s} in={str(e['in_shape']):>18s} "
              f"macs={e['macs']:>10d} "
              + ('fallback (no im2col)' if e['fallback'] else
                 f'im2col={us_i:8.1f}us kernel={us_k:8.1f}us'
                 + (' [depthwise]' if e.get('depthwise') else '')))
    return rows


def main():
    from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                                   VGG8_CIFAR)
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.models.cnn import cnn_forward, init_cnn
    from roofline import int8_serving_roofline

    ap = argparse.ArgumentParser()
    ap.add_argument('--batch', type=int, default=64)
    ap.add_argument('--iters', type=int, default=10)
    ap.add_argument('--pallas', action='store_true',
                    help='force the Pallas kernels (interpret mode on CPU '
                         '— correctness timing only, very slow)')
    ap.add_argument('--breakdown', action='store_true',
                    help='per-layer im2col/kernel timing + v5e roofline')
    ap.add_argument('--smoke', action='store_true',
                    help='tiny CI run: batch 8, 2 iters, no file output '
                         'unless --out is given')
    ap.add_argument('--out', default=None)
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.iters = min(args.batch, 8), min(args.iters, 2)
    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), 'BENCH_serving.json')

    # Same auto-dispatch rule export_cnn applies for use_pallas=None, made
    # explicit here so the recorded label always matches the timed path.
    # On CPU the jnp path serves convs as fp32 lax.conv with export-folded
    # scales (no int8 conv units) — the CPU win is static scales + folded
    # dequant + the cheap depthwise lowering, not int8 compute.
    use_pallas = args.pallas or jax.default_backend() == 'tpu'
    x = jax.random.normal(jax.random.key(0), (args.batch, 32, 32, 3))
    fam = CNNFamily(SyntheticImages())
    results = {'backend': jax.default_backend(),
               'batch': args.batch,
               'int8_path': 'pallas' if use_pallas else 'jnp-ref',
               'configs': {}}

    for base in (RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR):
        cfg = base.replace(w_bits=8, a_bits=8)
        params = init_cnn(jax.random.key(0), cfg)
        if base is RESNET8_CIFAR:      # early-exit serving entry
            params, cfg = fam.add_exits(jax.random.key(1), params,
                                        cfg.replace(exit_stages=()), (1,))
            cfg = cfg.replace(w_bits=8, a_bits=8)

        fake = jax.jit(lambda p, x, c=cfg: cnn_forward(p, c, x))
        us_fake = _time(fake, params, x, iters=args.iters)

        m = export_cnn(params, cfg, use_pallas=use_pallas)
        us_int8 = _time(m.fn, m.params, x, iters=args.iters)

        m_res = export_cnn(params, cfg, use_pallas=use_pallas, calibrate=x)
        us_res = _time(m_res.fn, m_res.params, x, iters=args.iters)

        entry = {'fakequant_fp32_us': round(us_fake, 1),
                 'exported_int8_us': round(us_int8, 1),
                 'int8_resident_us': round(us_res, 1),
                 'speedup': round(us_fake / us_int8, 3),
                 'resident_speedup': round(us_fake / us_res, 3),
                 'resident_vs_exported': round(us_int8 / us_res, 3),
                 'plan': m_res.summary()}
        if cfg.exit_stages:
            entry.update(_early_exit_entry(m, x, args.iters, threshold=0.85))

        # the 'fused' variant: the L-pass factored model, one-launch fused
        # kernel (forced) vs chained two-launch serving (same plan
        # otherwise), plus what the default cost model would actually ship
        fparams, _, mac_scale = fam.factorize(params, cfg, energy=0.6,
                                              min_rank=2)
        m_fused = export_cnn(fparams, cfg, use_pallas=use_pallas,
                             calibrate=x, select_kernels='fused')
        m_2l = export_cnn(fparams, cfg, use_pallas=use_pallas, calibrate=x,
                          fuse_lowrank=False)
        if m_fused.summary()['n_fused_lowrank'] > 0:
            m_sel = export_cnn(fparams, cfg, use_pallas=use_pallas,
                               calibrate=x)      # select_kernels='model'
            us_f = round(_time(m_fused.fn, m_fused.params, x,
                               iters=args.iters), 1)
            us_2 = round(_time(m_2l.fn, m_2l.params, x, iters=args.iters), 1)
            entry['fused'] = {
                'lowrank_mac_scale': round(mac_scale, 4),
                'n_fused_lowrank': m_fused.summary()['n_fused_lowrank'],
                'kernel_launches_fused':
                    m_fused.summary()['kernel_launches'],
                'kernel_launches_two_launch':
                    m_2l.summary()['kernel_launches'],
                'lowrank_fused_us': us_f,
                'lowrank_two_launch_us': us_2,
                'winner': 'fused' if us_f <= us_2 else 'chained',
                'model_selection': {
                    n: s['choice'] for n, s in
                    m_sel.summary()['lowrank_selection'].items()},
            }
            if args.smoke:
                # selection consistency: a measure-mode export must never
                # record a choice its own timings say is slower — the
                # launch-budget analyzer rule is the CI gate's version of
                # this contract, so the smoke shares it
                from repro.analysis import check
                m_meas = export_cnn(fparams, cfg, use_pallas=use_pallas,
                                    calibrate=x, select_kernels='measure')
                check(m_meas, x=x, rules=('launch-budget',), strict=True,
                      target=f'{cfg.name}:measure-smoke')
                entry['fused']['selection_consistent'] = True
                print(f'  smoke: measured selection consistent over '
                      f"{len(m_meas.summary()['lowrank_selection'])} layers")

        if args.smoke and 'mobilenet' in cfg.name:
            # the zero-fp32-MACs contract: depthwise serves on the int8
            # kernel, nothing falls back needlessly — int8-residency's
            # needless-fallback check is the rule-set version of the old
            # bespoke fallback==0 assert (mobilenet has no per-group
            # depth>1 convs, so any fallback is needless and errors)
            from repro.analysis import check
            check(m_res, x=x, rules=('int8-residency',), strict=True,
                  target=f'{cfg.name}:residency-smoke')
            s = entry['plan']
            assert s['n_depthwise'] > 0, s   # the kernel must actually run
            print(f"  smoke: mobilenet residency clean "
                  f"({s['n_depthwise']} depthwise layers on the int8 kernel)")

        if args.breakdown:
            print(f'{cfg.name} per-layer breakdown:')
            entry['layers'] = _breakdown(m_res, x, args.iters, use_pallas)
            # roofline over the plain serving path only — exit-head fc
            # layers are calibrated into the plan but fn never runs them
            # (LayerPlan.summary() splits them out the same way)
            entry['roofline_v5e'] = {
                k: (round(v, 9) if isinstance(v, float) else v)
                for k, v in int8_serving_roofline(
                    {n: e for n, e in m_res.plan.layers.items()
                     if not n.startswith('exit')}).items()}

        results['configs'][cfg.name] = entry
        print(f'{cfg.name}: fakequant_fp32={us_fake:.1f}us '
              f'exported_int8={us_int8:.1f}us '
              f'int8_resident={us_res:.1f}us '
              f'resident_vs_exported={us_int8 / us_res:.2f}x '
              f'(fallback MAC {entry["plan"]["fallback_mac_fraction"]:.1%})')

    if out:
        with open(out, 'w') as f:
            json.dump(results, f, indent=1)
        print(f'wrote {out}')


if __name__ == '__main__':
    main()
