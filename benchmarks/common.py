"""Shared scaffolding for the paper-reproduction experiment sweeps."""
from __future__ import annotations

import json
import os

import jax

from repro.configs.cnn import RESNET8_CIFAR
from repro.core.chain import run_chain, sweep_exit_thresholds
from repro.core.family import CNNFamily
from repro.core.passes import Trainer, init_chain_state
from repro.data import SyntheticImages

OUT_DIR = 'experiments/paper'

# Scaled-down protocol (CPU container): the paper trains 200 epochs per
# stage at lr and fine-tunes at lr/10; we keep the lr-ratio rule with a
# few hundred steps per stage.  Thresholds sweep gives E its frontier.
THRESHOLDS = (0.5, 0.7, 0.85, 0.95)

DEFAULT_HPS = {
    'D': {'factor': 0.75, 'temp': 2.0, 'alpha': 0.5},
    'P': {'ratio': 0.3},
    'Q': {'w_bits': 4, 'a_bits': 8},
    'E': {'threshold': 0.85},
}

# defaults for every registered pass (the chain validates hps against the
# sequence, so drivers must hand over exactly the keys they run)
FULL_HPS = dict(DEFAULT_HPS, L={'energy': 0.9})


def hps_for(sequence, overrides=None):
    """Per-key hp dicts for exactly the keys in ``sequence``, from FULL_HPS
    merged with ``overrides`` — keeps drivers registry-generic."""
    overrides = overrides or {}
    return {k: dict(FULL_HPS.get(k, {}), **overrides.get(k, {}))
            for k in dict.fromkeys(sequence)}


def make_family(difficulty=0.45):
    return CNNFamily(SyntheticImages(difficulty=difficulty), image=32)


def make_trainer(steps=120):
    return Trainer(batch=64, steps=steps, lr=2e-3, eval_n=2, eval_batch=256)


def baseline(fam, trainer, cfg=RESNET8_CIFAR, seed=0, pretrain_steps=None):
    return init_chain_state(fam, cfg, jax.random.key(seed), trainer,
                            pretrain_steps=pretrain_steps)


def chain_samples(fam, trainer, base, sequence, hps, *, allow_repeats=False):
    """Run a chain from a shared baseline; returns frontier samples
    [(acc, BitOpsCR)] — several per run when E is present (thresholds)."""
    import copy
    st = copy.copy(base)
    st.history = list(base.history)
    st = run_chain(fam, None, sequence, hps, trainer, state=st,
                   allow_repeats=allow_repeats)
    last = st.history[-1]
    samples = [(last['acc'], last['BitOpsCR'])]
    if 'E' in sequence:
        for rec in sweep_exit_thresholds(st, trainer, THRESHOLDS):
            samples.append((rec['acc'], rec['BitOpsCR']))
    return samples, st


def median_us(fn, *args, warmup=2, iters=10):
    """Median wall time of ``fn(*args)`` in microseconds.

    THE benchmark timing convention (BENCH_serving.json / BENCH_load.json
    must stay comparable): ``warmup`` un-timed runs to absorb jit
    compilation, then the median — never the mean — over ``iters`` timed
    runs, each fully materialized via block_until_ready (CI boxes are
    noisy; medians are the only defensible reduction)."""
    import statistics
    import time
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


def save_json(name, obj):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name), 'w') as f:
        json.dump(obj, f, indent=1)
    print(f'wrote {OUT_DIR}/{name}')
