"""Paper Figs. 6-11: pairwise order experiments A->B vs B->A.

For every pair of *registered* passes (core/registry.py — the paper's four
plus low-rank 'L' and any third-party pass), run both orders from a shared
trained baseline across a small hyperparameter grid, collect
(accuracy, BitOpsCR) samples, decide the winning order by Pareto-frontier
score, and feed the edges to the OrderPlanner's topological sort.  The run
validates the paper's claim that the resulting DAG is acyclic and reports
whether its unique sorting matches ``theoretical_order()`` over the full
registry (D->P->L->Q->E with the built-in five).  Exact score ties carry
no experimental evidence: they fall back to the theoretical order and are
recorded with margin 0.0 so ``resolve_cycles`` drops them first.

Usage: PYTHONPATH=src python -m benchmarks.pairwise_order [--steps 120]
"""
from __future__ import annotations

import argparse

from benchmarks import common
from repro.core.planner import OrderPlanner, compare_orders, theoretical_order

GRIDS = {
    'D': [{'factor': 0.75, 'temp': 2.0, 'alpha': 0.5}],
    'P': [{'ratio': 0.4}],
    'L': [{'energy': 0.9}],
    'Q': [{'w_bits': 4, 'a_bits': 8}],
    'E': [{'threshold': 0.85}],
}

WIDE_GRIDS = {                       # --wide: the paper's fuller sweep
    'D': [{'factor': 0.5}, {'factor': 0.35}],
    'P': [{'ratio': 0.3}, {'ratio': 0.5}],
    'L': [{'energy': 0.8}, {'energy': 0.95}],
    'Q': [{'w_bits': 2, 'a_bits': 8}, {'w_bits': 4, 'a_bits': 8}],
    'E': [{'threshold': 0.85}],
}


def run(steps=120, pairs=None, wide=False, keys=None):
    global GRIDS
    if wide:
        GRIDS = WIDE_GRIDS
    fam = common.make_family()
    tr = common.make_trainer(steps)
    base = common.baseline(fam, tr, pretrain_steps=steps * 3)
    planner = OrderPlanner(keys)            # None = the full registry
    results = {}
    pairs = pairs or planner.pairs()
    for a, b in pairs:
        samples = {'AB': [], 'BA': []}
        blocked = {'AB': None, 'BA': None}
        for hp_a in GRIDS.get(a, [{}]):
            for hp_b in GRIDS.get(b, [{}]):
                hps = {a: hp_a, b: hp_b}
                for d, seq in (('AB', a + b), ('BA', b + a)):
                    try:
                        s, _ = common.chain_samples(fam, tr, base, seq, hps)
                        samples[d] += s
                    except ValueError as e:
                        # structurally inapplicable order (e.g. L->P:
                        # channel-pruning a factored net) — itself evidence
                        # for the opposite order
                        blocked[d] = str(e)
        if blocked['AB'] and blocked['BA']:
            print(f'pair {a}{b}: both orders inapplicable, skipped')
            results[a + b] = {'winner': None, 'blocked': blocked}
            continue
        if blocked['AB'] or blocked['BA']:
            winner = 'BA' if blocked['AB'] else 'AB'
            order = a + b if winner == 'AB' else b + a
            planner.add_pairwise(a, b, winner)     # structural: full margin
            results[a + b] = {'winner': order, 'blocked': blocked,
                              'samples_' + a + b: samples['AB'],
                              'samples_' + b + a: samples['BA']}
            print(f'pair {a}{b}: winner {order} '
                  f'(reverse order inapplicable: '
                  f'{blocked["AB"] or blocked["BA"]})')
            continue
        winner, score_ab, score_ba = compare_orders(samples['AB'],
                                                    samples['BA'], a, b)
        order = a + b if winner == 'AB' else b + a
        planner.add_pairwise(a, b, winner, abs(score_ab - score_ba))
        results[a + b] = {'winner': order, 'score_' + a + b: score_ab,
                          'score_' + b + a: score_ba,
                          'samples_' + a + b: samples['AB'],
                          'samples_' + b + a: samples['BA']}
        print(f'pair {a}{b}: winner {order} '
              f'(score {score_ab:.4f} vs {score_ba:.4f})')
    dropped = planner.resolve_cycles()
    topo = planner.topological_order()
    theory = theoretical_order(planner.keys)
    print('topological order:', topo,
          f'(dropped weak edges: {dropped})' if dropped else '(acyclic)')
    print('theoretical order:', theory,
          '== empirical' if topo == theory else '!= empirical (investigate)')
    results['topological_order'] = topo
    results['theoretical_order'] = theory
    results['dropped_edges'] = dropped
    results['baseline_acc'] = base.history[0]['acc']
    common.save_json('pairwise_order.json', results)
    return results


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=120)
    ap.add_argument('--wide', action='store_true')
    ap.add_argument('--keys', default=None,
                    help='pass keys to plan (default: the full registry)')
    args = ap.parse_args()
    run(args.steps, wide=args.wide, keys=args.keys)
