"""Paper Figs. 6-11: pairwise order experiments A->B vs B->A.

For every pair of passes, run both orders from a shared trained baseline
across a small hyperparameter grid, collect (accuracy, BitOpsCR) samples,
decide the winning order by Pareto-frontier score, and feed the edges to
the OrderPlanner's topological sort.  The run validates the paper's claim
that the resulting DAG is acyclic with the unique sorting D->P->Q->E.

Usage: PYTHONPATH=src python -m benchmarks.pairwise_order [--steps 120]
"""
from __future__ import annotations

import argparse
import itertools

from benchmarks import common
from repro.core.planner import OrderPlanner, compare_orders

GRIDS = {
    'D': [{'factor': 0.75, 'temp': 2.0, 'alpha': 0.5}],
    'P': [{'ratio': 0.4}],
    'Q': [{'w_bits': 4, 'a_bits': 8}],
    'E': [{'threshold': 0.85}],
}

WIDE_GRIDS = {                       # --wide: the paper's fuller sweep
    'D': [{'factor': 0.5}, {'factor': 0.35}],
    'P': [{'ratio': 0.3}, {'ratio': 0.5}],
    'Q': [{'w_bits': 2, 'a_bits': 8}, {'w_bits': 4, 'a_bits': 8}],
    'E': [{'threshold': 0.85}],
}


def run(steps=120, pairs=None, wide=False):
    global GRIDS
    if wide:
        GRIDS = WIDE_GRIDS
    fam = common.make_family()
    tr = common.make_trainer(steps)
    base = common.baseline(fam, tr, pretrain_steps=steps * 3)
    planner = OrderPlanner('DPQE')
    results = {}
    pairs = pairs or list(itertools.combinations('DPQE', 2))
    for a, b in pairs:
        samples = {'AB': [], 'BA': []}
        for hp_a in GRIDS[a]:
            for hp_b in GRIDS[b]:
                hps = {a: hp_a, b: hp_b}
                s_ab, _ = common.chain_samples(fam, tr, base, a + b, hps)
                s_ba, _ = common.chain_samples(fam, tr, base, b + a, hps)
                samples['AB'] += s_ab
                samples['BA'] += s_ba
        winner, score_ab, score_ba = compare_orders(samples['AB'],
                                                    samples['BA'])
        order = a + b if winner == 'AB' else b + a
        planner.add_pairwise(a, b, winner, abs(score_ab - score_ba))
        results[a + b] = {'winner': order, 'score_' + a + b: score_ab,
                          'score_' + b + a: score_ba,
                          'samples_' + a + b: samples['AB'],
                          'samples_' + b + a: samples['BA']}
        print(f'pair {a}{b}: winner {order} '
              f'(score {score_ab:.4f} vs {score_ba:.4f})')
    dropped = planner.resolve_cycles()
    topo = planner.topological_order()
    print('topological order:', topo,
          f'(dropped weak edges: {dropped})' if dropped else '(acyclic)')
    results['topological_order'] = topo
    results['dropped_edges'] = dropped
    results['baseline_acc'] = base.history[0]['acc']
    common.save_json('pairwise_order.json', results)
    return results


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=120)
    ap.add_argument('--wide', action='store_true')
    args = ap.parse_args()
    run(args.steps, wide=args.wide)
