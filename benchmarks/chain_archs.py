"""Paper Tables 2-4 (+ beyond-paper LM transfer): the optimal chain on
every model family.

The sequence is taken from ``theoretical_order()`` over the *full pass
registry* (D->P->L->Q->E with the built-in five — grows automatically when
passes register), so this driver is the N-pass generalization of the
paper's DPQE tables.  CNN side (the paper's own): ResNet / VGG /
MobileNetV2 CIFAR-style configs on the synthetic image task.  LM side
(beyond paper): the chain applied to a reduced tinyllama and mixtral
(expert pruning) on the synthetic token task — demonstrating that the
sequence law is architecture-agnostic, which is the transferable claim of
the paper.

Usage: PYTHONPATH=src python -m benchmarks.chain_archs [--steps 120]
"""
from __future__ import annotations

import argparse

import jax

from benchmarks import common
from repro.configs import get_smoke_config
from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                               VGG8_CIFAR)
from repro.core.chain import run_chain
from repro.core.family import LMFamily
from repro.core.passes import Trainer, init_chain_state
from repro.core.planner import theoretical_order
from repro.data import SyntheticTokens


def run_cnn(steps=120, sequence=None):
    seq = sequence or theoretical_order()       # full registry: DPLQE
    fam = common.make_family()
    tr = common.make_trainer(steps)
    out = {'sequence': seq}
    for cfg in (RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR):
        base = init_chain_state(fam, cfg, jax.random.key(0), tr,
                                pretrain_steps=steps * 3)
        _, st = common.chain_samples(fam, tr, base, seq,
                                     common.hps_for(seq))
        out[cfg.name] = {'history': st.history}
        h0, h1 = st.history[0], st.history[-1]
        print(f"{cfg.name} [{seq}]: acc {h0['acc']:.3f} -> {h1['acc']:.3f}, "
              f"BitOpsCR {h1['BitOpsCR']:.0f}x, CR {h1['CR']:.1f}x")
    common.save_json('chain_cnn_archs.json', out)
    return out


def run_lm(steps=60, sequence=None):
    seq = sequence or theoretical_order()
    out = {'sequence': seq}
    for arch, overrides in (
            ('tinyllama-1.1b', {'P': {'ratio': 0.3}}),
            ('mixtral-8x7b', {'P': {'ratio': 0.5}})):     # expert pruning
        cfg = get_smoke_config(arch, layers=4).replace(vocab_size=256)
        fam = LMFamily(SyntheticTokens(vocab=cfg.vocab_size), seq=64)
        tr = Trainer(batch=16, steps=steps, lr=2e-3, eval_n=1,
                     eval_batch=64)
        base = init_chain_state(fam, cfg, jax.random.key(0), tr,
                                pretrain_steps=steps * 3)
        hps = common.hps_for(seq, dict(overrides,
                                       Q={'w_bits': 8, 'a_bits': 8}))
        st = run_chain(fam, None, seq, hps, tr, state=base)
        out[arch] = {'history': st.history}
        h0, h1 = st.history[0], st.history[-1]
        print(f"{arch} [{seq}]: acc {h0['acc']:.3f} -> {h1['acc']:.3f}, "
              f"BitOpsCR {h1['BitOpsCR']:.0f}x, CR {h1['CR']:.1f}x")
    common.save_json('chain_lm_archs.json', out)
    return out


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=120)
    ap.add_argument('--lm-steps', type=int, default=60)
    ap.add_argument('--skip-lm', action='store_true')
    ap.add_argument('--sequence', default=None,
                    help='override (default: theoretical_order() over the '
                         'registry)')
    args = ap.parse_args()
    run_cnn(args.steps, sequence=args.sequence)
    if not args.skip_lm:
        run_lm(args.lm_steps, sequence=args.sequence)
