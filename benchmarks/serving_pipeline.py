"""Pipeline-parallel serving benchmark: one device vs a placed pipeline.

Packs the exported CNN's stages onto multiple real jax devices with the
greedy-LPT cost solver (``repro/serving/placement.py``) and A/Bs three
schedulers on the SAME Poisson trace and the SAME measured per-stage
costs:

* ``single``          — the single-device continuous-batching scheduler
  (every segment serialized through one executor: the pipeline's lower
  bound is this run's makespan).
* ``pipeline``        — :class:`PipelineParallelScheduler`, compacting:
  stage *k* runs on its placed device, the int8 carry streams between
  devices (``transfer.carry``), survivors from any cohort backfill.
* ``pipeline_static`` — same placement, ``compact=False``: cohorts ride
  intact, exited slots stay empty (what compaction buys in device time).

Methodology matches serving_load.py: median per-stage costs at the fixed
slot geometry drive a simulated event clock while the data path executes
for real — here on N **forced host devices** (the benchmark re-execs
itself under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` when
the process has fewer devices than requested).  Every sampled request's
answer is checked bit-exact against the monolithic ``fn_exits`` serving
it alone at the same geometry, and the three schedulers must agree
answer-for-answer: placement moves WHERE stages run, never what they
compute.

Results go to BENCH_pipeline.json: the placement (assignment, loads,
LPT bound, balance), single vs pipeline makespan and the speedup, and
per-scheduler latency/throughput summaries with windowed ``timeseries``
blocks plus per-device ``device_occupancy`` series for the pipeline runs
(``summarize.py --diff-bench`` tracks them across generations).
``--smoke`` is the CI wiring: tiny trace, asserts drain + bit-exactness
+ strict trace invariants on the recorded pipeline spans, writes nothing
unless --out is given.

    PYTHONPATH=src python benchmarks/serving_pipeline.py [--devices 8]
    PYTHONPATH=src python benchmarks/serving_pipeline.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def ensure_devices(n: int) -> None:
    """Re-exec in a subprocess with ``n`` forced host devices when this
    process has fewer — the XLA device count is locked at backend init,
    so it cannot be raised in-process."""
    import jax
    if len(jax.devices()) >= n or os.environ.get('_REPRO_PIPE_REEXEC'):
        return
    env = dict(os.environ, _REPRO_PIPE_REEXEC='1', JAX_PLATFORMS='cpu')
    flags = [f for f in env.get('XLA_FLAGS', '').split()
             if not f.startswith('--xla_force_host_platform_device_count')]
    flags.append(f'--xla_force_host_platform_device_count={n}')
    env['XLA_FLAGS'] = ' '.join(flags)
    print(f'{len(jax.devices())} device(s) < {n}: re-running under '
          f'XLA_FLAGS={flags[-1]}')
    raise SystemExit(subprocess.call(
        [sys.executable, os.path.abspath(__file__)] + sys.argv[1:],
        env=env))


def makespan(completions) -> float:
    """Arrival of the first request -> completion of the last."""
    return (max(c.t_done for c in completions.values())
            - min(c.t_arrival for c in completions.values()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar')
    ap.add_argument('--slots', type=int, default=32)
    ap.add_argument('--requests', type=int, default=256)
    ap.add_argument('--iters', type=int, default=10)
    ap.add_argument('--devices', type=int, default=8,
                    help='forced host device count (re-execs if needed)')
    ap.add_argument('--rate', type=float, default=None,
                    help='arrival rate (req/s); default 2x the single-'
                         'device full-depth capacity, so the pipeline '
                         'win shows up as makespan, not idle time')
    ap.add_argument('--threshold', type=float, default=None)
    ap.add_argument('--quantile', type=float, default=0.5)
    ap.add_argument('--pallas', action='store_true')
    ap.add_argument('--transfer-frac', type=float, default=0.02,
                    help='carry-transfer charge as a fraction of the '
                         'consuming stage cost')
    ap.add_argument('--seed', type=int, default=0,
                    help='placement tie-break seed')
    ap.add_argument('--oracle-all', action='store_true')
    ap.add_argument('--trace', default=None, metavar='OUT.json',
                    help='write the pipeline run as validated '
                         'Chrome-trace JSON')
    ap.add_argument('--smoke', action='store_true',
                    help='tiny CI run: 24 requests, 8 slots, 2 iters, '
                         'full oracle, strict trace check, no file '
                         'output unless --out is given')
    ap.add_argument('--out', default=None)
    args = ap.parse_args()
    if args.smoke:
        args.slots, args.requests, args.iters = 8, 24, 2
    ensure_devices(args.devices)

    import jax
    import numpy as np

    from serving_load import (check_oracle, measure_stage_costs,
                              poisson_trace, validate_and_write_trace)
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import calibrate_exit_threshold, export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.kernels.tiling import batch_slots
    from repro.obs import Tracer, check_trace
    from repro.serving import (ContinuousBatchScheduler,
                               PipelineParallelScheduler)

    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), 'BENCH_pipeline.json')

    use_pallas = args.pallas or jax.default_backend() == 'tpu'
    slots = batch_slots(args.slots)
    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config].replace(w_bits=8, a_bits=8)
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params,
                                cfg.replace(exit_stages=()),
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)

    key = jax.random.key(7)
    xs = jax.random.normal(key, (args.requests, 32, 32, 3))
    calib = jax.random.normal(jax.random.fold_in(key, 1),
                              (slots, 32, 32, 3))
    model = export_cnn(params, cfg, use_pallas=use_pallas, calibrate=calib)
    threshold = args.threshold
    if threshold is None:
        threshold = calibrate_exit_threshold(model, calib,
                                             quantile=args.quantile)
        print(f'calibrated exit threshold: {threshold:.4f} '
              f'(target exit quantile {args.quantile})')

    stage_costs_us, mono_us = measure_stage_costs(
        model, calib, iters=args.iters)
    costs = [c * 1e-6 for c in stage_costs_us]

    # oversubscribe the single device's full-depth capacity so both
    # schedulers run queue-saturated and the A/B measures service rate
    rate = args.rate or 2.0 * slots / sum(costs)
    trace = poisson_trace(xs, rate, seed=0)

    single = ContinuousBatchScheduler(model, slots=slots,
                                      threshold=threshold,
                                      stage_costs=costs)
    s_comp, s_met = single.run_trace(trace)

    tracer = Tracer()
    pipe = PipelineParallelScheduler(
        model, slots=slots, threshold=threshold, stage_costs=costs,
        transfer_frac=args.transfer_frac, seed=args.seed, tracer=tracer)
    p_comp, p_met = pipe.run_trace(trace)
    placement = pipe.placement

    stat = PipelineParallelScheduler(
        model, slots=slots, threshold=threshold, stage_costs=costs,
        compact=False, transfer_frac=args.transfer_frac, seed=args.seed)
    t_comp, t_met = stat.run_trace(trace)

    runs = (('single', s_comp), ('pipeline', p_comp),
            ('pipeline_static', t_comp))
    for name, comp in runs:
        assert len(comp) == args.requests, \
            f'{name}: drained {len(comp)}/{args.requests}'
    oracle_reqs = (trace if (args.smoke or args.oracle_all)
                   else trace[:: max(1, len(trace) // 16)])
    for name, comp in runs:
        bad = check_oracle(model, comp, oracle_reqs, threshold, slots)
        assert not bad, f'{name}: requests {bad[:8]} diverge from oracle'
    for name, comp in runs[1:]:
        assert all(comp[r.rid].exit_stage == s_comp[r.rid].exit_stage
                   and np.array_equal(comp[r.rid].logits,
                                      s_comp[r.rid].logits)
                   for r in trace), f'{name} disagrees with single-device'

    check_trace(tracer, p_comp, strict=True)
    if args.trace:
        validate_and_write_trace(tracer, p_comp, args.trace)
    n_transfer = sum(1 for s in tracer.spans if s.name == 'transfer.carry')

    mk = {name: makespan(comp) for name, comp in runs}
    speedup = mk['single'] / max(mk['pipeline'], 1e-12)
    sums = {}
    for name, met in (('single', s_met), ('pipeline', p_met),
                      ('pipeline_static', t_met)):
        block = met.summary()
        block['makespan_s'] = round(mk[name], 6)
        block['timeseries'] = met.timeseries()
        occ = met.device_occupancy()
        if occ:
            block['device_occupancy'] = occ
        sums[name] = block

    results = {
        'backend': jax.default_backend(),
        'int8_path': 'pallas' if use_pallas else 'jnp-ref',
        'config': cfg.name,
        'n_devices': len(jax.devices()),
        'batch_geometry': {'slots_requested': args.slots,
                           'slots_padded': slots,
                           'image': [32, 32, 3]},
        'n_requests': args.requests,
        'arrival_rate_rps': round(rate, 3),
        'exit_threshold': round(threshold, 6),
        'transfer_frac': args.transfer_frac,
        'timing': {'iters': args.iters, 'reduction': 'median',
                   'stage_costs_us': [round(c, 1) for c in stage_costs_us],
                   'monolithic_us': round(mono_us, 1)},
        'placement': placement.summary(),
        'transfer_spans': n_transfer,
        'single': sums['single'],
        'pipeline': sums['pipeline'],
        'pipeline_static': sums['pipeline_static'],
        'pipeline_speedup_x': round(speedup, 3),
        'pipeline_vs_static_x': round(
            mk['pipeline_static'] / max(mk['pipeline'], 1e-12), 3),
    }
    print(f"{cfg.name} slots={slots} rate={rate:.0f}/s "
          f"devices={len(jax.devices())}")
    print(f"  placement: {placement.summary()['assignment']} "
          f"loads={placement.summary()['loads']} "
          f"balance={placement.balance:.3f} "
          f"(LPT bound {placement.bound * 1e3:.3f}ms)")
    for name, _ in runs:
        b = sums[name]
        print(f"  {name + ':':17s}makespan={b['makespan_s'] * 1e3:.2f}ms "
              f"p99={b['p99_latency_s'] * 1e3:.2f}ms "
              f"throughput={b['throughput_rps']:.0f} req/s")
    print(f"  pipeline speedup: {speedup:.2f}x vs single "
          f"({results['pipeline_vs_static_x']:.2f}x vs static cohorts); "
          f"{n_transfer} carry transfers")
    occ = sums['pipeline'].get('device_occupancy', {})
    for d in sorted(occ, key=int):
        bar = ''.join('#' if v > 0.5 else ('+' if v > 0 else '.')
                      for v in occ[d])
        print(f"    device{d} [{bar}]")
    if args.smoke:
        print('pipeline smoke OK: drained, bit-exact vs single-device '
              'and oracle, trace invariants hold')
    if out:
        with open(out, 'w') as f:
            json.dump(results, f, indent=1)
        print(f'wrote {out}')


if __name__ == '__main__':
    main()
