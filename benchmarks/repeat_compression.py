"""Paper Sec. 6 / Fig. 14: repeating a compression after the optimal chain.

Compares (a) one aggressive application of P/Q vs two mild repeats, and
(b) the DPQE chain followed by a repeated P or Q — validating the paper's
finding that repetition does not beat the optimal single-pass sequence
(except continuous Q, which trades accuracy).

Usage: PYTHONPATH=src python -m benchmarks.repeat_compression [--steps 100]
"""
from __future__ import annotations

import argparse

from benchmarks import common
from repro.core.passes import PASSES


def run(steps=100):
    fam = common.make_family()
    tr = common.make_trainer(steps)
    base = common.baseline(fam, tr, pretrain_steps=steps * 3)
    out = {}

    def metrics_of(st, label):
        h = st.history[-1]
        out[label] = {'acc': h['acc'], 'BitOpsCR': h['BitOpsCR']}
        print(f"{label:14s} acc={h['acc']:.3f} BitOpsCR={h['BitOpsCR']:.1f}x")

    # single-pass aggressive vs mild repeated: pruning
    _, st = common.chain_samples(fam, tr, base, 'P', {'P': {'ratio': 0.6}})
    metrics_of(st, 'P_aggressive')
    _, st = common.chain_samples(fam, tr, base, 'PP', {'P': {'ratio': 0.37}},
                                 allow_repeats=True)
    metrics_of(st, 'P_repeated')

    # quantization
    _, st = common.chain_samples(fam, tr, base, 'Q',
                                 {'Q': {'w_bits': 2, 'a_bits': 8}})
    metrics_of(st, 'Q_aggressive')
    _, st = common.chain_samples(fam, tr, base, 'QQ',
                                 {'Q': {'w_bits': 4, 'a_bits': 8}},
                                 allow_repeats=True)
    # second Q re-runs at 2 bits
    st = PASSES['Q'].apply(st, {'w_bits': 2, 'a_bits': 8}, tr)
    st.metrics(tr, 'Q2')
    metrics_of(st, 'Q_repeated')

    # DPQE then repeat P / Q
    _, chain = common.chain_samples(fam, tr, base, 'DPQE',
                                    common.DEFAULT_HPS)
    metrics_of(chain, 'DPQE')
    st = PASSES['P'].apply(chain, {'ratio': 0.2}, tr)
    st.metrics(tr, 'DPQE+P')
    metrics_of(st, 'DPQE_repeatP')
    st = PASSES['Q'].apply(chain, {'w_bits': 1, 'a_bits': 8}, tr)
    st.metrics(tr, 'DPQE+Q')
    metrics_of(st, 'DPQE_repeatQ')

    common.save_json('repeat_compression.json', out)
    return out


if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=100)
    args = ap.parse_args()
    run(args.steps)
