"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Each entry times the computational
primitive behind a paper artifact and reports the paper-relevant derived
metric next to it.  The full experiment *sweeps* (which train many models)
live in benchmarks/pairwise_order.py, sequence_law.py, chain_archs.py and
write JSON consumed by EXPERIMENTS.md; this harness is the fast,
deterministic timing pass.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

ROWS = []


def bench(name, fn, *args, derived='', warmup=2, iters=8):
    out = None
    for _ in range(warmup):
        out = fn(*args)
        if out is not None:
            jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        if out is not None:
            jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    ROWS.append((name, us, derived))
    print(f'{name},{us:.1f},{derived}')
    return out


def table1_sequence_law():
    """Primitive: one fine-tune step of the chain (resnet8); derived: the
    DPQE BitOpsCR bound from the cost model at the default chain hps."""
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core import bitops as bo
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), RESNET8_CIFAR)
    batch = fam.train_batch(jax.random.key(1), 64)
    grad = jax.jit(jax.grad(lambda p: fam.loss(p, RESNET8_CIFAR, batch)[0]))
    base = bo.cnn_bitops(RESNET8_CIFAR)
    dpqe = fam.bitops(RESNET8_CIFAR.replace(w_bits=2, a_bits=8,
                                            exit_stages=(1,)),
                      exit_probs={1: 0.5}, mac_scale=0.7)
    bench('table1_chain_finetune_step', grad, params,
          derived=f'DPQE_model_BitOpsCR={base / dpqe:.0f}x')


def tables234_cnn_forward():
    """Primitive: forward pass of each CNN family at CIFAR shape."""
    from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                                   VGG8_CIFAR)
    from repro.core import bitops as bo
    from repro.models.cnn import cnn_forward, init_cnn
    x = jax.random.normal(jax.random.key(0), (64, 32, 32, 3))
    for cfg in (RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR):
        p = init_cnn(jax.random.key(0), cfg)
        f = jax.jit(lambda p, x, c=cfg: cnn_forward(p, c, x))
        bench(f'table234_forward_{cfg.kind}', f, p, x,
              derived=f'MACs={bo.cnn_bitops(cfg) / (32 * 32):.3g}')


def fig15_per_stage_costs():
    """Derived-only: BitOpsCR after each stage of the optimal chain, from
    the cost model (the measured curve comes from chain_archs.py)."""
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core import bitops as bo
    cfg = RESNET8_CIFAR
    base = bo.cnn_bitops(cfg)
    crs = [2.0]                                          # D: depth/2 student
    crs.append(crs[-1] / 0.7)                            # P: 30% channels
    crs.append(base * 2 / 0.7
               / bo.cnn_bitops(cfg.replace(w_bits=2, a_bits=8)))
    crs.append(base * 2 / 0.7
               / bo.cnn_bitops(cfg.replace(w_bits=2, a_bits=8,
                                           exit_stages=(1,)),
                               exit_probs={1: 0.5}))
    d = '|'.join(f'{c:.0f}x' for c in crs)
    ROWS.append(('fig15_stage_crs', 0.0, d))
    print(f'fig15_stage_crs,0.0,{d}')


def kernel_benchmarks():
    """Kernels vs their oracles (ref on CPU; Pallas compiles for TPU)."""
    from repro.kernels import ref
    k = jax.random.key(0)
    xq = jax.random.randint(k, (256, 1024), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(k, 1), (1024, 512),
                            -128, 128, jnp.int8)
    sx = jnp.full((256,), 0.01)
    sw = jnp.full((512,), 0.01)
    f = jax.jit(ref.quant_matmul_ref)
    bench('kernel_quant_matmul_ref', f, xq, wq, sx, sw,
          derived='int8_256x1024x512')
    w = jax.random.normal(k, (2048, 2048))
    g = jax.jit(lambda w: ref.fake_quant_ref(w, 8))
    bench('kernel_fake_quant_ref', g, w, derived='8bit_2048x2048')
    q = jax.random.normal(k, (4, 16, 128))
    kk = jax.random.normal(k, (4, 2048, 8, 128))
    vv = jax.random.normal(k, (4, 2048, 8, 128))
    valid = jnp.ones((4, 2048), bool)
    h = jax.jit(ref.decode_attention_ref)
    bench('kernel_decode_attn_ref', h, q, kk, vv, valid,
          derived='B4_S2048_H16')


def serving_and_training_steps():
    """Train-step and decode-step latency for a reduced LM config."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config('tinyllama-1.1b', layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {'tokens': jax.random.randint(jax.random.key(1), (4, 128), 0,
                                          cfg.vocab_size)}
    lossf = jax.jit(jax.grad(lambda p: jnp.mean(
        m.forward(p, batch).astype(jnp.float32))))
    bench('lm_train_grad_step', lossf, params, derived='4x128_smoke')
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=256))(params,
                                                                  batch)
    tok = jnp.zeros((4,), jnp.int32)
    dec = jax.jit(lambda p, t, c: m.decode_step(p, t, jnp.asarray(128), c)[0])
    bench('lm_decode_step', dec, params, tok, cache, derived='B4_ctx128')


def checkpoint_roundtrip():
    import tempfile
    from repro.checkpoint import save_checkpoint, load_checkpoint
    tree = {'w': jnp.zeros((1024, 1024)), 'b': jnp.zeros((1024,))}
    d = tempfile.mkdtemp()

    def save():
        save_checkpoint(d, 0, tree)
    bench('checkpoint_save_4MB', save, derived='atomic_npz')

    def load():
        return load_checkpoint(d, 0, tree)[0]['w']
    bench('checkpoint_load_4MB', load, derived='')


def main() -> None:
    print('name,us_per_call,derived')
    table1_sequence_law()
    tables234_cnn_forward()
    fig15_per_stage_costs()
    kernel_benchmarks()
    serving_and_training_steps()
    checkpoint_roundtrip()


if __name__ == '__main__':
    main()
