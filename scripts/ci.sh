#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps (best effort — the container may be
# offline, in which case hypothesis-only modules skip themselves), run the
# pass-registry consistency check and the quickstart smoke (registry API +
# tiny P->L->Q pipeline through int8 export), then the canonical test
# command from ROADMAP.md.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: pip install failed (offline?); property tests will skip"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# every registered pass must carry (kind, granularity) ranks the planner
# knows, and a defaulted hp dataclass — a bad registration fails CI here
python - <<'PY'
import repro.core  # populates the registry (D/P/Q/E + L)
from repro.core import registry
keys = registry.check_consistency()
print('registry consistent:', ''.join(keys))
PY

python examples/quickstart.py --smoke

# serving-benchmark smoke: times the fake-quant / dynamic-int8 /
# int8-resident paths (incl. the fused low-rank variant) on a tiny batch —
# catches export-plan regressions that only bite at serve time.  Also
# runs the analyzer's int8-residency and launch-budget rules over the
# exports (mobilenet must have no needless fallback; a measure-mode export
# never records a fused/chained choice its own timings say is slower).
# Writes no BENCH file (the committed BENCH_serving.json comes from a full
# run).
python benchmarks/serving_int8.py --smoke

# serving-runtime smoke: a tiny Poisson trace through the continuous-
# batching scheduler — asserts the queue drains and every request's answer
# is bit-exact vs the monolithic model serving it alone at the same slot
# geometry (the early-exit compaction contract).  Writes no BENCH file.
python benchmarks/serving_load.py --smoke

# resilience smoke: the same trace (plus arrival bursts) through the
# replica pool under a seeded chaos plan — a replica killed mid-batch and
# a straggler slowdown.  Asserts the pool drains with zero lost requests,
# fails over through the registry restore path, and every completion is
# bit-exact vs the undisturbed run; the chaos+SLO leg asserts no admitted
# request ever finishes past its deadline.  Writes no BENCH file.
python benchmarks/serving_load.py --smoke --chaos

# trace smoke: the chaos smoke again with --trace — the run must emit a
# valid Chrome-trace JSON whose spans pass the strict invariant check
# (nesting, per-replica serial execution, latency == span extent) with
# the kill + failover story visible on the replica tracks; then the
# validator itself is proven live by mutating a span (tearing t1 < t0)
# and requiring check_trace to go red on the mutated file.
python benchmarks/serving_load.py --smoke --chaos \
    --trace /tmp/trace_smoke.json
python - <<'PY'
import json
from repro.obs import check_trace, load_chrome_trace

spans = load_chrome_trace('/tmp/trace_smoke.json')
assert not check_trace(spans, strict=False), 'smoke trace has violations'
assert any(s.name == 'stage.exec' and s.args.get('killed') for s in spans)
assert any(s.name == 'failover.restore' for s in spans)

with open('/tmp/trace_smoke.json') as f:
    doc = json.load(f)
for ev in doc['traceEvents']:          # tear one stage.exec span
    if ev.get('ph') == 'X' and ev.get('name') == 'stage.exec':
        ev['dur'] = -ev['dur'] - 1
        break
torn = check_trace(load_chrome_trace(doc), strict=False)
assert torn, 'check_trace stayed green on a torn span'
print(f'trace smoke OK: {len(spans)} spans valid, '
      f'torn-span mutation caught ({len(torn)} violation(s))')
PY

# pipeline-parallel smoke, on 8 forced host devices (the benchmark
# re-execs itself under the forced count; JAX_PLATFORMS=cpu keeps the
# lane deterministic on any box): serves the same tiny trace through the
# single-device scheduler and the placed pipeline, asserting every
# request bit-exact vs the monolithic oracle and the recorded spans
# (incl. transfer.carry) strictly valid.  Then the placement-consistency
# rule is proven live: green on the pipeline's placed export, red on the
# stage-assignment-dropping mutant.  Writes no BENCH file.
JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/serving_pipeline.py --smoke
python - <<'PY'
import jax
from repro.analysis import check
from repro.analysis.mutations import MUTANTS, _resnet_export

model, _, _, x = _resnet_export(use_pallas=False, exits=True)
placed = model.place_stages((jax.devices()[0],) * model.n_stages)
clean = check(model=placed, x=x, rules=('placement-consistency',),
              target='ci:placed-export')
assert not any(f.severity == 'error' for f in clean.findings), clean
red = check(**MUTANTS['placement-consistency']())
errs = [f for f in red.findings if f.severity == 'error']
assert errs, 'placement-consistency stayed green on its mutant'
print(f'placement-consistency OK: clean export green, '
      f'mutant red ({len(errs)} error finding(s))')
PY

# static-analysis gate (repro/analysis): every rule must be green on the
# shipped exports of all three CNN kinds (both backends + the theoretical
# sequence) AND red on its deliberately-mutated export — a rule that stops
# firing on its own mutant fails CI even while everything stays green.
# Any error-severity finding on a clean export exits non-zero here.
python -m repro.analysis.gate

exec python -m pytest -x -q "$@"
