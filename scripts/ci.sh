#!/usr/bin/env bash
# Tier-1 CI entry point: install dev deps (best effort — the container may be
# offline, in which case hypothesis-only modules skip themselves) and run the
# canonical test command from ROADMAP.md.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: pip install failed (offline?); property tests will skip"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
