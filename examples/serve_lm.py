"""Serving example: batched prefill + decode with a KV cache, including the
int8 quantized-matmul serving path (the paper's Q pass at inference) and
per-request early exit accounting.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.core.bitops import lm_bitops
from repro.data import SyntheticTokens
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='gemma2-9b', choices=ARCH_NAMES)
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=32)
    ap.add_argument('--tokens', type=int, default=16)
    ap.add_argument('--w-bits', type=int, default=0,
                    help='8 -> serve with fake-quantized weights (Q pass)')
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.w_bits:
        cfg = cfg.replace(w_bits=args.w_bits, a_bits=8)
    if cfg.arch_kind == 'encdec':
        raise SystemExit('use whisper decode via tests; this example is '
                         'decoder-only serving')
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticTokens(vocab=cfg.vocab_size)
    prompt = {'tokens': data.batch(jax.random.key(1), args.batch,
                                   args.prompt_len)['tokens']}
    if cfg.arch_kind == 'vlm':
        prompt['patches'] = jnp.zeros((args.batch, cfg.frontend_tokens,
                                       cfg.d_model))

    max_len = args.prompt_len + args.tokens + 8
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(lambda p, t, c, cache: model.decode_step(p, t, c,
                                                              cache))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = args.prompt_len + (cfg.frontend_tokens
                              if cfg.arch_kind == 'vlm' else 0)
    outs = [tok]
    t0 = time.perf_counter()
    for t in range(args.tokens):
        logits, cache = decode(params, tok, jnp.asarray(pos0 + t,
                                                        jnp.int32), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = (time.perf_counter() - t0) / args.tokens

    bops = lm_bitops(cfg, args.prompt_len, decode=True,
                     ctx_len=args.prompt_len + args.tokens)
    print(f'arch={cfg.name} w_bits={cfg.w_bits or 32}')
    print(f'prefill {args.batch}x{args.prompt_len}: {t_prefill * 1e3:.1f} ms')
    print(f'decode: {t_decode * 1e3:.1f} ms/token '
          f'({args.batch} sequences in flight)')
    print(f'BitOps/token (cost model): {bops:.3g}')
    print('sampled:', [int(t[0]) for t in outs])


if __name__ == '__main__':
    main()
