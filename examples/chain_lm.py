"""Beyond-paper: the compression chain applied to an LM architecture.

Distills a reduced tinyllama into a shallower student, prunes FFN channels
(physically — dense gathers, TPU-friendly), QAT-quantizes to int8, and adds
early-exit heads — the same D->P->Q->E law, architecture-transferred.

    PYTHONPATH=src python examples/chain_lm.py --arch tinyllama-1.1b
"""
import argparse

import jax

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.core.chain import run_chain
from repro.core.family import LMFamily
from repro.core.passes import Trainer, init_chain_state
from repro.data import SyntheticTokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='tinyllama-1.1b', choices=ARCH_NAMES)
    ap.add_argument('--steps', type=int, default=80)
    ap.add_argument('--layers', type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch, layers=args.layers).replace(
        vocab_size=256)
    fam = LMFamily(SyntheticTokens(vocab=cfg.vocab_size), seq=64)
    tr = Trainer(batch=16, steps=args.steps, lr=2e-3, eval_n=1,
                 eval_batch=64)
    print(f'== training baseline {cfg.name} ==')
    st = init_chain_state(fam, cfg, jax.random.key(0), tr,
                          pretrain_steps=args.steps * 3)
    seq = 'DPQE'
    if cfg.ssm_state:
        seq = 'DQE'          # channel pruning inapplicable to SSD state
        print('(ssm family: P skipped — see DESIGN.md arch-applicability)')
    defaults = {'D': {'factor': 0.5}, 'P': {'ratio': 0.3},
                'Q': {'w_bits': 8, 'a_bits': 8},
                'E': {'threshold': 0.8}}
    # the pipeline rejects hps for keys outside the sequence: hand over
    # exactly what runs
    st = run_chain(fam, None, seq, {k: defaults[k] for k in seq},
                   tr, state=st)
    print(f"\n{'stage':10s} {'next-tok acc':>12s} {'BitOpsCR':>10s} "
          f"{'CR':>8s}")
    for h in st.history:
        print(f"{h['pass']:10s} {h['acc']:12.3f} {h['BitOpsCR']:9.1f}x "
              f"{h['CR']:7.1f}x")


if __name__ == '__main__':
    main()
