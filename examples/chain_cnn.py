"""End-to-end driver (paper-native): train a CIFAR-style CNN for a few
hundred steps, then compress it with an ordered pass sequence (default:
the paper's D->P->Q->E; pass --sequence DPLQE for the 5-pass law with
low-rank factorization) and report accuracy / BitOpsCR / CR per stage.

    PYTHONPATH=src python examples/chain_cnn.py --model resnet8-cifar \
        --steps 300

Any registered pass key works in --sequence (core/registry.py) — the
pipeline validates the sequence and only accepts hps for keys in it.
"""
import argparse

import jax

from repro.configs.cnn import CNN_REGISTRY
from repro.core.chain import OPTIMAL_SEQUENCE, Pipeline
from repro.core.family import CNNFamily
from repro.core.passes import Trainer, init_chain_state
from repro.data import SyntheticImages


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--model', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--steps', type=int, default=300,
                    help='fine-tune steps per stage (pretrain = 3x)')
    ap.add_argument('--sequence', default=OPTIMAL_SEQUENCE)
    ap.add_argument('--w-bits', type=int, default=2)
    ap.add_argument('--prune-ratio', type=float, default=0.3)
    ap.add_argument('--energy', type=float, default=0.9,
                    help="low-rank 'L' spectral-energy threshold")
    args = ap.parse_args()

    fam = CNNFamily(SyntheticImages(difficulty=0.55), image=32)
    tr = Trainer(batch=64, steps=args.steps, lr=2e-3, eval_n=2,
                 eval_batch=256)
    print(f'== training baseline {args.model} ({args.steps * 3} steps) ==')
    st = init_chain_state(fam, CNN_REGISTRY[args.model], jax.random.key(0),
                          tr, pretrain_steps=args.steps * 3)
    print(f'== compressing with sequence {args.sequence} ==')
    defaults = {'D': {'factor': 0.5}, 'P': {'ratio': args.prune_ratio},
                'L': {'energy': args.energy},
                'Q': {'w_bits': args.w_bits, 'a_bits': 8},
                'E': {'threshold': 0.85}}
    hps = {k: defaults[k] for k in args.sequence if k in defaults}
    st = Pipeline.from_sequence(args.sequence, hps).run(fam, None, tr,
                                                        state=st)
    print(f"\n{'stage':10s} {'acc':>7s} {'BitOpsCR':>10s} {'CR':>8s}")
    for h in st.history:
        print(f"{h['pass']:10s} {h['acc']:7.3f} {h['BitOpsCR']:9.1f}x "
              f"{h['CR']:7.1f}x")


if __name__ == '__main__':
    main()
