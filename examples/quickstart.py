"""Quickstart: build any assigned architecture, train a few steps, decode.

    PYTHONPATH=src python examples/quickstart.py --arch tinyllama-1.1b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import adamw, apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='tinyllama-1.1b', choices=ARCH_NAMES)
    ap.add_argument('--steps', type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)           # reduced config: runs on CPU
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticTokens(vocab=cfg.vocab_size)
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        def loss(p):
            lg = model.forward(p, batch)
            lp = jax.nn.log_softmax(lg.astype(jnp.float32))
            return -jnp.mean(jnp.take_along_axis(
                lp, batch['labels'][..., None], -1))
        l, g = jax.value_and_grad(loss)(params)
        u, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, u), opt_state, l

    for i in range(args.steps):
        batch = data.batch(jax.random.key(i), 8, 64)
        params, opt_state, l = step(params, opt_state, batch)
        if i % 5 == 0:
            print(f'step {i:3d} loss {float(l):.3f}')

    # greedy decode a few tokens
    if cfg.arch_kind in ('decoder', 'vlm'):
        prompt = {'tokens': data.batch(jax.random.key(99), 1, 16)['tokens']}
        if cfg.arch_kind == 'vlm':
            prompt['patches'] = jnp.zeros((1, cfg.frontend_tokens,
                                           cfg.d_model), jnp.float32)
        logits, cache = model.prefill(params, prompt, max_len=64)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [int(tok[0])]
        pos0 = 16 + (cfg.frontend_tokens if cfg.arch_kind == 'vlm' else 0)
        for t in range(8):
            logits, cache = model.decode_step(
                params, tok, jnp.asarray(pos0 + t, jnp.int32), cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(tok[0]))
        print('decoded continuation:', out)


if __name__ == '__main__':
    main()
