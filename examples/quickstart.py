"""Quickstart: build any assigned architecture, train a few steps, decode.

    PYTHONPATH=src python examples/quickstart.py --arch tinyllama-1.1b

Serving the compressed model (--serve-cnn): after the compression chain,
the export pass compiles the fake-quant params down to a genuinely-int8
serving function on the Pallas kernels — static per-channel weight scales
snapshot once at export, convs on kernels/quant_conv.py, fcs on
kernels/quant_matmul.py, early exits served batched:

    PYTHONPATH=src python examples/quickstart.py --serve-cnn

CI smoke (--smoke): registry-consistency check + a tiny P→L→Q pipeline
through int8 export, exercising the full pass-registry API in seconds.

Migration note (old PASSES dict → registry): compression passes are now
first-class registry entries (core/registry.py) with typed hyperparameter
dataclasses; build chains with ``Pipeline.from_sequence('DPLQE', hps)``
(core/chain.py) instead of indexing the old closed ``PASSES`` dict —
which survives as a live read-only view for existing call sites.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import adamw, apply_updates


def serve_cnn_demo():
    """Serving the compressed model: QAT params → int8 export → batched
    early-exit inference.  See core/export.py for the pass itself."""
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages

    fam = CNNFamily(SyntheticImages())
    params = fam.init(jax.random.key(0), RESNET8_CIFAR)
    params, cfg = fam.add_exits(jax.random.key(1), params, RESNET8_CIFAR,
                                fam.default_exit_points(RESNET8_CIFAR))
    cfg = cfg.replace(w_bits=8, a_bits=8)       # the chain's Q pass sets these

    model = export_cnn(params, cfg)             # scales snapshot ONCE, here
    x, _ = fam.eval_batches(1, 16)[0]
    logits = model.serve(x)                     # int8 conv/matmul kernels
    pred, stage = model.serve_early_exit(x, threshold=0.85)
    print('int8 serving logits:', logits.shape,
          'early-exit stages:', [int(s) for s in stage])


def smoke_demo():
    """CI smoke: pass-registry consistency, then a tiny P→L→Q pipeline
    (typed hps, validated sequence) compiled to int8 serving."""
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core import registry
    from repro.core.chain import Pipeline
    from repro.core.family import CNNFamily
    from repro.core.passes import Trainer, init_chain_state
    from repro.core.planner import theoretical_order
    from repro.data import SyntheticImages

    keys = registry.check_consistency()
    print('registry consistent:', ''.join(keys))
    print('theoretical order over registry:', theoretical_order())

    fam = CNNFamily(SyntheticImages())
    tr = Trainer(batch=16, steps=2, eval_n=1, eval_batch=32)
    st = init_chain_state(fam, RESNET8_CIFAR, jax.random.key(0), tr,
                          pretrain_steps=2)
    pipe = Pipeline.from_sequence('PLQ', {'P': {'ratio': 0.3},
                                          'L': {'energy': 0.9},
                                          'Q': {'w_bits': 8, 'a_bits': 8}})
    st = pipe.run(fam, None, tr, state=st)
    model = pipe.export(st)
    x, _ = fam.eval_batches(1, 8)[0]
    print('smoke: stages', [h['pass'] for h in st.history],
          'served int8 logits', tuple(model.serve(x).shape))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='tinyllama-1.1b', choices=ARCH_NAMES)
    ap.add_argument('--steps', type=int, default=20)
    ap.add_argument('--serve-cnn', action='store_true',
                    help='demo: export + serve an int8 compressed CNN')
    ap.add_argument('--smoke', action='store_true',
                    help='CI smoke: registry check + tiny pipeline + export')
    args = ap.parse_args()

    if args.smoke:
        smoke_demo()
        return
    if args.serve_cnn:
        serve_cnn_demo()
        return

    cfg = get_smoke_config(args.arch)           # reduced config: runs on CPU
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticTokens(vocab=cfg.vocab_size)
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        def loss(p):
            lg = model.forward(p, batch)
            lp = jax.nn.log_softmax(lg.astype(jnp.float32))
            return -jnp.mean(jnp.take_along_axis(
                lp, batch['labels'][..., None], -1))
        l, g = jax.value_and_grad(loss)(params)
        u, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, u), opt_state, l

    for i in range(args.steps):
        batch = data.batch(jax.random.key(i), 8, 64)
        params, opt_state, l = step(params, opt_state, batch)
        if i % 5 == 0:
            print(f'step {i:3d} loss {float(l):.3f}')

    # greedy decode a few tokens
    if cfg.arch_kind in ('decoder', 'vlm'):
        prompt = {'tokens': data.batch(jax.random.key(99), 1, 16)['tokens']}
        if cfg.arch_kind == 'vlm':
            prompt['patches'] = jnp.zeros((1, cfg.frontend_tokens,
                                           cfg.d_model), jnp.float32)
        logits, cache = model.prefill(params, prompt, max_len=64)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [int(tok[0])]
        pos0 = 16 + (cfg.frontend_tokens if cfg.arch_kind == 'vlm' else 0)
        for t in range(8):
            logits, cache = model.decode_step(
                params, tok, jnp.asarray(pos0 + t, jnp.int32), cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(tok[0]))
        print('decoded continuation:', out)


if __name__ == '__main__':
    main()
