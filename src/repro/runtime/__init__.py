from repro.runtime.ft import FaultTolerantLoop, SimulatedFailure  # noqa: F401
from repro.runtime.elastic import reshard_tree, elastic_restore  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
