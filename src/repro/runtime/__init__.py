"""Training-fleet runtime: fault tolerance, elastic restore, stragglers.

These primitives were built for the training loop (checkpoint-resume
under simulated host failures, reshard-on-load across pod slices, EWMA
straggler detection on the synchronous fleet).  The serving runtime
(``repro.serving``) folds the same ideas into the request path: the
replica pool (``serving/replica.py``) uses :class:`SimulatedFailure` as
its chaos-kill payload, re-keys :class:`StragglerMonitor` from hosts to
replicas (``observe_one``), and reuses elastic.py's load-driven scaling
idea at request level.
"""
from repro.runtime.ft import FaultTolerantLoop, SimulatedFailure  # noqa: F401
from repro.runtime.elastic import reshard_tree, elastic_restore  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
