"""Elastic scaling: reshard a training state onto a different mesh.

When a pod (or any slice) is lost, the job restarts on the surviving
hardware: the checkpoint is loaded as host arrays and re-placed under the
*new* mesh's shardings.  Symmetrically, scale-up re-places onto a larger
mesh.  Batch-size semantics are preserved by keeping the *global* batch
fixed and letting the per-device batch grow/shrink (the step function is
compiled against global shapes, so only shardings change, not math).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding


def reshard_tree(tree, shardings):
    """device_put every leaf onto the matching sharding (host round-trip ok)."""
    def place(x, s):
        if isinstance(x, jax.Array) and not isinstance(s, NamedSharding):
            return x
        return jax.device_put(np.asarray(x), s)
    return jax.tree.map(place, tree, shardings)


def shardings_for(tree, mesh, spec_fn):
    """Build a sharding pytree: spec_fn(path, leaf) -> PartitionSpec."""
    def one(path, leaf):
        return NamedSharding(mesh, spec_fn(path, leaf))
    return jax.tree_util.tree_map_with_path(one, tree)


def elastic_restore(ckpt_manager, tree_like, new_mesh, spec_fn):
    """Restore the latest checkpoint onto a (possibly different-size) mesh."""
    state, step = ckpt_manager.restore_latest(tree_like)
    shardings = shardings_for(state, new_mesh, spec_fn)
    return reshard_tree(state, shardings), step
