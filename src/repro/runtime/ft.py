"""Fault-tolerant training loop: checkpoint/restart with failure injection.

At 1000+ nodes the MTBF of the fleet is minutes–hours, so the training
driver, not the operator, must own recovery.  The loop:

  * checkpoints (async) every ``ckpt_every`` steps via CheckpointManager,
  * treats any exception from the step function (injected or real — e.g. a
    host dropping out surfaces as a collective error) as a failure event,
  * restores the latest committed checkpoint, rewinds the data iterator to
    the restored step (the synthetic pipeline is deterministic-by-step, so
    rewind = recompute), and resumes,
  * gives up after ``max_restarts`` consecutive failures at the same step
    (a poison-pill guard, distinguishing transient node loss from a
    deterministic bug).

On real multi-pod deployments the restore path goes through
``elastic_restore`` so a lost pod can be dropped from the mesh (see
runtime/elastic.py); the logic here is mesh-size agnostic.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint import CheckpointManager

log = logging.getLogger('repro.ft')


class SimulatedFailure(RuntimeError):
    """Raised by failure injectors in tests/drills."""


@dataclass
class FaultTolerantLoop:
    step_fn: Callable                    # (state, batch) -> (state, metrics)
    batch_fn: Callable                   # (step) -> batch   (deterministic!)
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 5
    failure_injector: Callable | None = None   # (step) -> None | raise
    restarts: int = field(default=0, init=False)
    events: list = field(default_factory=list, init=False)

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        fails_here = 0
        while step < start_step + num_steps:
            try:
                if self.failure_injector is not None:
                    self.failure_injector(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, self.batch_fn(step))
                dt = time.monotonic() - t0
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
                step += 1
                fails_here = 0
                self.events.append(('step', step, dt, metrics))
            except Exception as e:                        # noqa: BLE001
                fails_here += 1
                self.restarts += 1
                self.events.append(('failure', step, repr(e)))
                log.warning('step %d failed (%s); restoring', step, e)
                if fails_here > self.max_restarts:
                    raise RuntimeError(
                        f'step {step} failed {fails_here}x — poison pill'
                    ) from e
                try:
                    state, restored = self.ckpt.restore_latest(state)
                    step = restored + 1
                except FileNotFoundError:
                    step = start_step       # no checkpoint yet: cold restart
        self.ckpt.save(step - 1, state)
        self.ckpt.wait()
        return state, step
