"""Straggler detection + mitigation hooks.

On a synchronous SPMD fleet a straggling host delays every step (the
collectives act as barriers).  Mitigation implemented here:

  * detection — EWMA of per-step wall time with a multiplicative threshold;
  * data reassignment — because the input pipeline is deterministic in
    (step, host_id), a slow host's shard can be re-mapped to a hot spare by
    permuting host_ids (no data loss, no resharding);
  * escalation — after ``evict_after`` consecutive flags the host is
    reported for eviction, which triggers the elastic path
    (runtime/elastic.py) on the next restart.

On-device timing comes from the launcher; in tests times are injected.

The serving replica pool (repro/serving/replica.py) re-keys the monitor to
*replicas*: hosts are replica ids and the observed quantity is each
batch's cost normalized by the expected stage cost (healthy ~1.0), fed one
at a time through :meth:`StragglerMonitor.observe_one` as batches land —
a flagged replica is de-prioritized for new dispatches and, after
``evict_after`` consecutive flags, replaced through the failover path.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 1.5          # x EWMA before a host is flagged
    alpha: float = 0.2
    evict_after: int = 3
    ewma: float | None = field(default=None, init=False)
    flags: dict = field(default_factory=dict, init=False)
    host_map: list = field(default=None, init=False)    # logical -> physical
    spares: list = field(default_factory=list)

    def __post_init__(self):
        self.host_map = list(range(self.n_hosts))

    def observe(self, host_times: dict[int, float]):
        """Feed per-host step times; returns list of mitigation actions."""
        actions = []
        mean = sum(host_times.values()) / len(host_times)
        self.ewma = mean if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * mean
        for h, t in host_times.items():
            if t > self.threshold * self.ewma:
                self.flags[h] = self.flags.get(h, 0) + 1
                if self.spares:
                    spare = self.spares.pop(0)
                    idx = self.host_map.index(h)
                    self.host_map[idx] = spare
                    actions.append(('reassign', h, spare))
                if self.flags[h] >= self.evict_after:
                    actions.append(('evict', h))
            else:
                self.flags.pop(h, None)
        return actions

    def observe_one(self, host: int, t: float):
        """Feed ONE host's observation (the serving pool's re-keying:
        batches land one at a time, ``t`` is the batch cost normalized by
        the expected stage cost).  Updates the fleet EWMA and this host's
        flag count; returns mitigation actions — ``('flag', host)`` on
        each threshold crossing and ``('evict', host)`` after
        ``evict_after`` consecutive ones.  Hosts need not be < n_hosts
        (replica ids grow as the pool fails over); the host_map/spares
        machinery is untouched."""
        actions = []
        self.ewma = t if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * t
        if t > self.threshold * self.ewma:
            self.flags[host] = self.flags.get(host, 0) + 1
            actions.append(('flag', host))
            if self.flags[host] >= self.evict_after:
                actions.append(('evict', host))
        else:
            self.flags.pop(host, None)
        return actions

    def flagged(self, host: int) -> bool:
        """Is ``host`` currently flagged as a straggler?"""
        return self.flags.get(host, 0) > 0

    def data_host_id(self, logical_host: int) -> int:
        """Physical host currently serving a logical data shard."""
        return self.host_map[logical_host]
