"""deepseek-v3-671b — [arXiv:2412.19437; hf] MLA, 1 shared + 256 routed top-8.

First 3 layers are dense FFN (d_ff=18432); remaining layers are MoE with
expert dim 2048 (the assignment's d_ff=2048 is the per-expert dim). MLA:
q_lora 1536, kv_lora 512, rope head dim 64, nope head dim 128, v head 128.
MTP (multi-token prediction) is implemented as an optional extra head.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='deepseek-v3-671b', family='moe',
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=192,      # rope(64) + nope(128) per-head q/k dim
    d_ff=18432, vocab_size=129_280,
    block_pattern=('global',),
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
)
