"""whisper-small — [arXiv:2212.04356; unverified] enc-dec; conv frontend stubbed.

The audio frontend (log-mel + conv) is a stub: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model) for the encoder.
12 heads are not divisible by the 16-way model axis → shard_heads=False
(attention replicated, FFN tensor-parallel; whisper-small is tiny so TP on
attention is not load-bearing).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='whisper-small', family='audio',
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51_865,
    block_pattern=('global',),
    arch_kind='encdec', num_encoder_layers=12, frontend_tokens=1500,
    shard_heads=False,
)
