"""mamba2-2.7b — [arXiv:2405.21060; unverified] SSD (state-space duality), attn-free.

d_inner = 2*d_model = 5120, headdim 64 → 80 SSD heads, state N=128,
ngroups=1 (B/C shared across heads). Decode carries (B, heads, headdim, N)
recurrent state — O(1) per token, so long_500k runs natively.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='mamba2-2.7b', family='ssm',
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50_280,
    block_pattern=('ssm',),
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    max_seq_len=1_048_576,
)
