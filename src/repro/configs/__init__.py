"""Architecture config registry.

``get_config(name)`` returns the full published config;
``get_smoke_config(name)`` returns the reduced CPU-testable variant.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, reduced

from repro.configs.gemma2_9b import CONFIG as _gemma2_9b
from repro.configs.gemma3_12b import CONFIG as _gemma3_12b
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.mamba2_2_7b import CONFIG as _mamba2

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        _gemma2_9b, _gemma3_12b, _tinyllama, _qwen2, _rgemma,
        _mixtral, _dsv3, _whisper, _internvl, _mamba2,
    ]
}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f'unknown arch {name!r}; known: {sorted(REGISTRY)}')
    return REGISTRY[name]


def get_smoke_config(name: str, **kw) -> ModelConfig:
    return reduced(get_config(name), **kw)
