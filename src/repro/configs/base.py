"""Model configuration dataclass shared by all assigned architectures.

Every architecture in ``src/repro/configs/<id>.py`` instantiates a
:class:`ModelConfig`.  The transformer stack in ``repro.models`` is driven
entirely by this config — block pattern, attention flavour (GQA / MLA /
local), MoE, RG-LRU and Mamba-2 SSD blocks are all selected per layer from
``block_pattern``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention structure ----------------------------------------------
    # Repeating unit of block kinds, cycled over layers.
    # kinds: 'global' | 'local' | 'recurrent' | 'ssm'
    block_pattern: tuple = ('global',)
    window: int = 4096               # sliding-window size for 'local' blocks
    logit_softcap: float = 0.0       # final-logit soft capping (gemma2)
    attn_softcap: float = 0.0        # attention-logit soft capping (gemma2)
    qkv_bias: bool = False           # qwen2-style bias on QKV projections
    rope_theta: float = 10_000.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek-v3: leading dense layers
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # --- RG-LRU (recurrentgemma) ----------------------------------------------
    rglru_width: int = 0
    rglru_conv: int = 4

    # --- structural kind --------------------------------------------------------
    arch_kind: str = 'decoder'       # decoder | encdec | vlm
    num_encoder_layers: int = 0      # encdec only
    frontend_tokens: int = 0         # vlm patches / audio frames (stubbed input)
    max_seq_len: int = 131_072

    # --- numerics / sharding profile ---------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = 'bfloat16'
    shard_heads: bool = True         # False when num_heads % model-axis != 0

    # --- compression hooks (paper technique) ---------------------------------------
    w_bits: int = 0                  # 0 = full precision (no fake-quant)
    a_bits: int = 0
    kv_cache_bits: int = 0           # 8 -> int8 KV cache (serving)
    exit_layers: tuple = ()          # indices of layers with early-exit heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> tuple:
        """Expanded per-layer kind list (length == num_layers)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def replace(self, **kw) -> 'ModelConfig':
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """Shrink a full config to a CPU-smoke-testable size, same family/pattern.

    Keeps the block pattern (at least one full repeat), divisibility of heads,
    and all structural flags, so the smoke test exercises the same code paths
    as the full config.
    """
    pat = len(cfg.block_pattern)
    n_layers = layers if layers is not None else max(pat, 2)
    kw = dict(
        name=cfg.name + '-smoke',
        num_layers=n_layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, 64),
        max_seq_len=256,
        dtype='float32',
    )
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                  n_shared_experts=cfg.n_shared_experts,
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.use_mla:
        kw.update(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32, head_dim=48)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32,
                  num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0)
    if cfg.rglru_width:
        kw.update(rglru_width=128)
    if cfg.arch_kind == 'encdec':
        kw.update(num_encoder_layers=2)
    if cfg.frontend_tokens:
        kw.update(frontend_tokens=8)
    return cfg.replace(**kw)
