"""internvl2-2b — [arXiv:2404.16821; hf] InternViT (stub) + InternLM2-1.8B backbone.

The ViT frontend is a stub: ``input_specs()`` provides precomputed patch
embeddings (batch, patches, d_model) prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='internvl2-2b', family='vlm',
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92_553,
    block_pattern=('global',),
    arch_kind='vlm', frontend_tokens=256,
)
