"""Paper-native CNN family configs (ResNet-CIFAR / VGG / MobileNetV2-style).

The paper's own experiments run on ResNet34 / VGG19 / MobileNetV2 over
CIFAR-style 32x32 inputs.  We keep the same family structure at scalable
width/depth so the full chain (D->P->Q->E) reproduces on CPU in minutes.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str                 # resnet | vgg | mobilenet
    num_classes: int = 10
    in_channels: int = 3
    # resnet: blocks per stage; vgg: convs per stage; mobilenet: inverted residuals per stage
    stage_blocks: tuple = (2, 2, 2)
    stage_widths: tuple = (16, 32, 64)
    expand_ratio: int = 4     # mobilenet inverted-bottleneck expansion
    # compression hooks
    w_bits: int = 0
    a_bits: int = 0
    exit_stages: tuple = ()   # stages after which an early-exit head sits

    def replace(self, **kw) -> 'CNNConfig':
        return replace(self, **kw)


RESNET34_CIFAR = CNNConfig(
    name='resnet34-cifar', kind='resnet',
    stage_blocks=(3, 4, 6, 3), stage_widths=(64, 128, 256, 512))

RESNET8_CIFAR = CNNConfig(     # CPU-scale stand-in used by the repro benchmarks
    name='resnet8-cifar', kind='resnet',
    stage_blocks=(1, 1, 1), stage_widths=(16, 32, 64))

VGG19_CIFAR = CNNConfig(
    name='vgg19-cifar', kind='vgg',
    stage_blocks=(2, 2, 4, 4, 4), stage_widths=(64, 128, 256, 512, 512))

VGG8_CIFAR = CNNConfig(
    name='vgg8-cifar', kind='vgg',
    stage_blocks=(1, 1, 2), stage_widths=(16, 32, 64))

MOBILENETV2_CIFAR = CNNConfig(
    name='mobilenetv2-cifar', kind='mobilenet',
    stage_blocks=(1, 2, 3, 2), stage_widths=(16, 24, 32, 64), expand_ratio=6)

MOBILENET_SMALL_CIFAR = CNNConfig(
    name='mobilenet-small-cifar', kind='mobilenet',
    stage_blocks=(1, 1, 1), stage_widths=(8, 16, 32), expand_ratio=4)

CNN_REGISTRY = {c.name: c for c in [
    RESNET34_CIFAR, RESNET8_CIFAR, VGG19_CIFAR, VGG8_CIFAR,
    MOBILENETV2_CIFAR, MOBILENET_SMALL_CIFAR]}
