"""gemma3-12b — [hf:google/gemma-3; unverified] 5:1 local:global, 128k context."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='gemma3-12b', family='dense',
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262_144,
    block_pattern=('local',) * 5 + ('global',), window=1024,
    rope_theta=1_000_000.0, tie_embeddings=True, max_seq_len=131_072,
)
