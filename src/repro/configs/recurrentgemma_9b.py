"""recurrentgemma-9b (Griffin) — [arXiv:2402.19427; unverified] RG-LRU + local attn 1:2.

Pattern is (recurrent, recurrent, local-attention) repeating; 38 layers =
12 full groups + 2 tail recurrent layers. MQA (kv=1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='recurrentgemma-9b', family='hybrid',
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256_000,
    block_pattern=('recurrent', 'recurrent', 'local'), window=2048,
    rglru_width=4096, tie_embeddings=True,
)
