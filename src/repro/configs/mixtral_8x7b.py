"""mixtral-8x7b — [arXiv:2401.04088; hf] 8 experts top-2, sliding-window attn."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='mixtral-8x7b', family='moe',
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32_000,
    block_pattern=('local',), window=4096,
    n_experts=8, top_k=2, moe_d_ff=14336,
)
