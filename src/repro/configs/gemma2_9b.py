"""gemma2-9b — [arXiv:2408.00118; hf] local+global alternating, logit softcap."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='gemma2-9b', family='dense',
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256_000,
    block_pattern=('local', 'global'), window=4096,
    logit_softcap=30.0, attn_softcap=50.0,
    tie_embeddings=True, max_seq_len=8192 * 64,
)
