"""Span tracing for the serving stack, with a Chrome-trace exporter.

A :class:`Tracer` collects :class:`Span` records from a scheduler run
(simulated clock) or an export (wall clock) — the span taxonomy is fixed
(see ``serving/README.md``):

=====================  ========================================================
``request.queue``      async span per request: arrival (or requeue after a
                       kill) -> service start; lives on the request's cohort
                       track, correlated by rid.
``request.admit``      instant at an SLO admission decision (rejections).
``stage.exec``         one executed segment batch on a replica/executor
                       track, with ``stage``/``live``/``slots``/``rids``
                       attributes (``killed=True`` when a chaos kill
                       truncated it).
``compaction``         instant after a non-final segment lands: how many
                       slots exited vs survived.
``failover.restore``   checkpoint restore of a replacement replica, on the
                       NEW replica's track.
``export.calibrate``   wall-clock span around the layer-plan compile.
``kernel.launch``      one timed kernel execution during measure-mode
                       selection (these spans ARE the measurement).
=====================  ========================================================

Timestamps are float seconds on whichever clock produced them; serving
spans (simulated clock) and export spans (wall clock) land in different
trace *processes*, so the two timelines never mix on one track.

:data:`NULL_TRACER` (a :class:`NullTracer`) is the default everywhere: its
methods are no-ops that allocate nothing, so the uninstrumented hot path
pays one attribute check (``tracer.enabled``) and no span bookkeeping.

``to_chrome()`` emits the Chrome trace-event JSON format (the ``'X'`` /
``'b'``/``'e'`` / ``'i'`` / ``'C'`` phases) that https://ui.perfetto.dev
and chrome://tracing load directly: one thread per replica, one per
request cohort, grouped into ``serving`` / ``requests`` / ``export``
processes.  :func:`load_chrome_trace` parses that JSON back into spans so
a written trace file is a checkable artifact
(:func:`repro.obs.validate.check_trace`), not just a picture.
"""
from __future__ import annotations

import json
import re
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

SPAN = 'span'          # nested duration on one track
ASYNC = 'async'        # request-lifetime span, correlated by cid (rid)
INSTANT = 'instant'    # point event
COUNTER = 'counter'    # sampled value (rendered as a counter track)

# track-name prefix -> (pid, process name); unknown prefixes go to 'misc'
_PID_GROUPS = (('replica', 1, 'serving'), ('executor', 1, 'serving'),
               ('device', 1, 'serving'), ('scheduler', 1, 'serving'),
               ('cohort', 2, 'requests'), ('export', 3, 'export'))


@dataclass(frozen=True)
class Span:
    """One trace event: a duration (``kind='span'``/``'async'``), an
    instant (``t1 == t0``), or a counter sample (``args={'value': v}``)."""
    name: str
    t0: float
    t1: float
    track: str
    kind: str = SPAN
    cid: int | None = None        # async correlation id (the rid)
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Collects spans; ``enabled`` lets call sites skip building args."""

    enabled = True

    def __init__(self):
        self.spans: list[Span] = []
        self._wall0 = time.perf_counter()

    def now(self) -> float:
        """Wall-clock seconds since this tracer was created (the export
        timeline; scheduler spans carry their own simulated times)."""
        return time.perf_counter() - self._wall0

    def add(self, name, t0, t1, *, track, **args) -> None:
        self.spans.append(Span(name, float(t0), float(t1), track,
                               kind=SPAN, args=args))

    def async_span(self, name, t0, t1, *, track, cid, **args) -> None:
        self.spans.append(Span(name, float(t0), float(t1), track,
                               kind=ASYNC, cid=int(cid), args=args))

    def instant(self, name, t, *, track, **args) -> None:
        self.spans.append(Span(name, float(t), float(t), track,
                               kind=INSTANT, args=args))

    def counter(self, name, t, value, *, track='counters') -> None:
        self.spans.append(Span(name, float(t), float(t), track,
                               kind=COUNTER, args={'value': float(value)}))

    @contextmanager
    def span(self, name, *, track, **args):
        """Wall-clock duration span around a ``with`` body."""
        t0 = self.now()
        try:
            yield
        finally:
            self.add(name, t0, self.now(), track=track, **args)

    # ------------------------------------------------------- chrome export

    def to_chrome(self) -> dict:
        return spans_to_chrome(self.spans)

    def write(self, path) -> None:
        with open(path, 'w') as f:
            json.dump(self.to_chrome(), f)


class NullTracer(Tracer):
    """The default: every method is an allocation-free no-op."""

    enabled = False

    def __init__(self):                      # no span list, no clock
        pass

    def now(self):
        return 0.0

    def add(self, name, t0, t1, *, track, **args):
        pass

    def async_span(self, name, t0, t1, *, track, cid, **args):
        pass

    def instant(self, name, t, *, track, **args):
        pass

    def counter(self, name, t, value, *, track='counters'):
        pass

    @contextmanager
    def span(self, name, *, track, **args):
        yield

    def to_chrome(self):
        return spans_to_chrome(())

    @property
    def spans(self):
        return []


NULL_TRACER = NullTracer()


def as_tracer(tracer) -> Tracer:
    """None -> the shared :data:`NULL_TRACER`; anything else passes."""
    return NULL_TRACER if tracer is None else tracer


def _pid_for(track: str) -> tuple[int, str]:
    for prefix, pid, pname in _PID_GROUPS:
        if track.startswith(prefix):
            return pid, pname
    return 9, 'misc'


def _track_sort_key(track: str):
    """Natural sort so replica10 follows replica9, not replica1."""
    m = re.match(r'^(.*?)(\d+)$', track)
    return (m.group(1), int(m.group(2))) if m else (track, -1)


def spans_to_chrome(spans) -> dict:
    """Chrome trace-event JSON: ``ts``/``dur`` in microseconds, integer
    pid/tid, metadata events naming the processes and tracks."""
    tracks = sorted({s.track for s in spans}, key=_track_sort_key)
    tids, events = {}, []
    per_pid_next = {}
    for track in tracks:
        pid, pname = _pid_for(track)
        tid = per_pid_next.get(pid, 1)
        per_pid_next[pid] = tid + 1
        tids[track] = (pid, tid)
        if tid == 1:
            events.append({'ph': 'M', 'name': 'process_name', 'pid': pid,
                           'tid': 0, 'args': {'name': pname}})
        events.append({'ph': 'M', 'name': 'thread_name', 'pid': pid,
                       'tid': tid, 'args': {'name': track}})
        events.append({'ph': 'M', 'name': 'thread_sort_index', 'pid': pid,
                       'tid': tid, 'args': {'sort_index': tid}})
    for s in spans:
        pid, tid = tids[s.track]
        base = {'name': s.name, 'pid': pid, 'tid': tid,
                'ts': s.t0 * 1e6, 'args': dict(s.args)}
        if s.kind == SPAN:
            events.append({**base, 'ph': 'X', 'cat': 'serving',
                           'dur': s.dur * 1e6})
        elif s.kind == ASYNC:
            cid = f'0x{s.cid:x}'
            events.append({**base, 'ph': 'b', 'cat': s.name, 'id': cid})
            events.append({'name': s.name, 'pid': pid, 'tid': tid,
                           'ts': s.t1 * 1e6, 'ph': 'e', 'cat': s.name,
                           'id': cid, 'args': {}})
        elif s.kind == INSTANT:
            events.append({**base, 'ph': 'i', 's': 't'})
        elif s.kind == COUNTER:
            events.append({'name': s.name, 'pid': pid, 'tid': tid,
                           'ts': s.t0 * 1e6, 'ph': 'C',
                           'args': {s.name: s.args.get('value', 0.0)}})
    return {'traceEvents': events, 'displayTimeUnit': 'ms'}


def load_chrome_trace(path_or_dict) -> list[Span]:
    """Parse a Chrome trace (path or already-loaded dict) back into
    :class:`Span` records.  Raises ``ValueError`` on a torn async pair
    (a ``'b'`` with no matching ``'e'`` or vice versa) — a trace that
    cannot round-trip is itself a bug."""
    if isinstance(path_or_dict, dict):
        doc = path_or_dict
    else:
        with open(path_or_dict) as f:
            doc = json.load(f)
    events = doc.get('traceEvents', doc if isinstance(doc, list) else [])
    names = {}                             # (pid, tid) -> track name
    for e in events:
        if e.get('ph') == 'M' and e.get('name') == 'thread_name':
            names[(e['pid'], e['tid'])] = e['args']['name']
    def track(e):
        return names.get((e.get('pid', 0), e.get('tid', 0)),
                         f"pid{e.get('pid', 0)}.tid{e.get('tid', 0)}")
    spans, open_async = [], {}
    for e in events:
        ph = e.get('ph')
        t = e.get('ts', 0.0) / 1e6
        if ph == 'X':
            spans.append(Span(e['name'], t, t + e.get('dur', 0.0) / 1e6,
                              track(e), kind=SPAN,
                              args=dict(e.get('args', {}))))
        elif ph == 'b':
            key = (e.get('cat'), e.get('id'), e['name'])
            open_async.setdefault(key, []).append((t, track(e),
                                                   dict(e.get('args', {}))))
        elif ph == 'e':
            key = (e.get('cat'), e.get('id'), e['name'])
            pend = open_async.get(key)
            if not pend:
                raise ValueError(f'torn async span: end with no begin '
                                 f'for {key}')
            t0, trk, args = pend.pop(0)
            if not pend:
                del open_async[key]
            cid = e.get('id')
            cid = int(cid, 16) if isinstance(cid, str) else int(cid)
            spans.append(Span(e['name'], t0, t, trk, kind=ASYNC,
                              cid=cid, args=args))
        elif ph == 'i':
            spans.append(Span(e['name'], t, t, track(e), kind=INSTANT,
                              args=dict(e.get('args', {}))))
        elif ph == 'C':
            args = dict(e.get('args', {}))
            v = args.get(e['name'], next(iter(args.values()), 0.0))
            spans.append(Span(e['name'], t, t, track(e), kind=COUNTER,
                              args={'value': float(v)}))
    if open_async:
        raise ValueError(f'torn async span(s): begin with no end for '
                         f'{sorted(open_async)}')
    return spans
