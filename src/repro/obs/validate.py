"""Trace validation: the invariants that make a trace a load-bearing
test artifact.

:func:`check_trace` takes spans (a :class:`~repro.obs.trace.Tracer`, a
span list, or a trace-file path) and optionally the run's completions
(``{rid: Completion}``) and asserts:

1. **well-formed** — every span has finite, ordered times (``t1 >= t0``)
   and a non-empty name/track; ``stage.exec`` spans carry their ``stage``
   attribute.
2. **nesting** — duration spans on one track either nest properly or are
   disjoint; partial overlap means two records claim the same executor
   for incompatible intervals.
3. **replica serialism** — ``stage.exec`` spans on one replica/executor
   track never overlap: a replica runs one batch at a time, including
   killed flights (which end at the kill, before the replacement runs).
4. **latency extent** (with completions) — a completion's span tree spans
   exactly its latency: its first ``request.queue`` span starts at
   ``t_arrival``, its last ends at ``t_start`` (so queue-wait equals the
   gap between arrival and first segment-0 ``stage.exec``), and — for
   non-degraded completions — the last ``stage.exec`` containing the rid
   ends at ``t_done`` while a segment-0 ``stage.exec`` starts at
   ``t_start``.  Degraded completions are resolved by the SLO sweep
   between batches, so only their queue invariants apply.

Returns a list of violation strings (empty = clean); ``strict=True``
raises :class:`TraceInvariantError` instead.  Requests that were
rejected (or whose only dispatch was killed) legitimately leave queue
spans with no completion; those are not flagged.
"""
from __future__ import annotations

import math

from repro.obs.trace import ASYNC, SPAN, Span, Tracer, load_chrome_trace

_EPS = 1e-9
_EXEC_TRACKS = ('replica', 'executor', 'device')


class TraceInvariantError(AssertionError):
    """Raised by ``check_trace(..., strict=True)`` on any violation."""

    def __init__(self, violations):
        self.violations = list(violations)
        super().__init__('trace invariants violated:\n  ' +
                         '\n  '.join(self.violations))


def _coerce(spans_or_tracer) -> list[Span]:
    if isinstance(spans_or_tracer, Tracer):
        return list(spans_or_tracer.spans)
    if isinstance(spans_or_tracer, (str, bytes)) or hasattr(
            spans_or_tracer, '__fspath__'):
        return load_chrome_trace(spans_or_tracer)
    return list(spans_or_tracer)


def _near(a, b, tol=_EPS) -> bool:
    return abs(a - b) <= tol * max(1.0, abs(a), abs(b))


def check_trace(spans_or_tracer, completions=None, *,
                strict: bool = False) -> list[str]:
    """Validate span invariants; see the module docstring."""
    spans = _coerce(spans_or_tracer)
    v: list[str] = []

    # 1. well-formed
    for s in spans:
        if not (math.isfinite(s.t0) and math.isfinite(s.t1)):
            v.append(f'{s.name}@{s.track}: non-finite time '
                     f'[{s.t0}, {s.t1}]')
        elif s.t1 < s.t0 - _EPS:
            v.append(f'{s.name}@{s.track}: torn span (t1 {s.t1:.9f} < '
                     f't0 {s.t0:.9f})')
        if not s.name or not s.track:
            v.append(f'span with empty name/track at t={s.t0}')
        if s.name == 'stage.exec' and 'stage' not in s.args:
            v.append(f'stage.exec@{s.track} t={s.t0:.6f}: missing '
                     f'"stage" attribute')

    by_track: dict[str, list[Span]] = {}
    for s in spans:
        if s.kind == SPAN and s.t1 >= s.t0 - _EPS:
            by_track.setdefault(s.track, []).append(s)

    # 2. nesting: sorted by (t0, -t1) a child always follows its parent
    for track, ts in by_track.items():
        ts.sort(key=lambda s: (s.t0, -s.t1))
        stack: list[Span] = []
        for s in ts:
            while stack and stack[-1].t1 <= s.t0 + _EPS:
                stack.pop()
            if stack and s.t1 > stack[-1].t1 + _EPS:
                v.append(f'{track}: {s.name} [{s.t0:.6f}, {s.t1:.6f}] '
                         f'partially overlaps {stack[-1].name} '
                         f'[{stack[-1].t0:.6f}, {stack[-1].t1:.6f}]')
            else:
                stack.append(s)

    # 3. per-replica serial execution
    for track, ts in by_track.items():
        if not track.startswith(_EXEC_TRACKS):
            continue
        execs = sorted((s for s in ts if s.name == 'stage.exec'),
                       key=lambda s: s.t0)
        for a, b in zip(execs, execs[1:]):
            if b.t0 < a.t1 - _EPS:
                v.append(f'{track}: concurrent stage.exec spans '
                         f'[{a.t0:.6f}, {a.t1:.6f}] and '
                         f'[{b.t0:.6f}, {b.t1:.6f}]')

    # 4. completion extents
    if completions:
        queue_by_rid: dict[int, list[Span]] = {}
        exec_by_rid: dict[int, list[Span]] = {}
        for s in spans:
            if s.kind == ASYNC and s.name == 'request.queue':
                queue_by_rid.setdefault(s.cid, []).append(s)
            elif s.name == 'stage.exec' and not s.args.get('killed'):
                for rid in s.args.get('rids', ()):
                    exec_by_rid.setdefault(int(rid), []).append(s)
        for rid, c in completions.items():
            qs = sorted(queue_by_rid.get(rid, []), key=lambda s: s.t0)
            if not qs:
                v.append(f'rid {rid}: completion with no request.queue '
                         f'span')
                continue
            if not _near(qs[0].t0, c.t_arrival):
                v.append(f'rid {rid}: first queue span starts at '
                         f'{qs[0].t0:.9f}, arrival was '
                         f'{c.t_arrival:.9f}')
            if c.t_start is not None and not _near(qs[-1].t1, c.t_start):
                v.append(f'rid {rid}: queue-wait mismatch — last queue '
                         f'span ends at {qs[-1].t1:.9f}, service started '
                         f'at {c.t_start:.9f}')
            if c.degraded:
                continue            # resolved by the SLO sweep, not a batch
            es = exec_by_rid.get(rid, [])
            if not es:
                v.append(f'rid {rid}: completion with no stage.exec span')
                continue
            t_done = max(s.t1 for s in es)
            if not _near(t_done, c.t_done):
                v.append(f'rid {rid}: latency extent mismatch — last '
                         f'stage.exec ends at {t_done:.9f}, completion at '
                         f'{c.t_done:.9f}')
            if c.t_start is not None and not any(
                    s.args.get('stage') == 0 and _near(s.t0, c.t_start)
                    for s in es):
                v.append(f'rid {rid}: no segment-0 stage.exec starting at '
                         f't_start={c.t_start:.9f}')

    if strict and v:
        raise TraceInvariantError(v)
    return v
