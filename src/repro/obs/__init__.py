"""Runtime observability: span tracing (Chrome-trace export) and trace
validation for the serving stack.  See serving/README.md (Observability)
for the span taxonomy and clock semantics."""
from repro.obs.trace import (NULL_TRACER, NullTracer, Span, Tracer,
                             as_tracer, load_chrome_trace, spans_to_chrome)
from repro.obs.validate import TraceInvariantError, check_trace

__all__ = ['NULL_TRACER', 'NullTracer', 'Span', 'Tracer', 'as_tracer',
           'load_chrome_trace', 'spans_to_chrome', 'TraceInvariantError',
           'check_trace']
