"""Request-level schedulers: continuous batching with early-exit compaction
and (optionally) SLO-aware admission/degradation.

Two schedulers share one contract (``run_trace(requests) -> (completions,
metrics)``) so the load benchmark can A/B them on the same arrival trace:

* :class:`StaticBatchScheduler` — the pre-PR-4 deployment: fill a batch
  from the queue, run the monolithic ``fn_exits`` to FULL depth, apply the
  early-exit rule afterwards.  Exits change which head answers but save no
  compute: one hard sample holds every exited slot hostage to full depth.

* :class:`ContinuousBatchScheduler` — the model's layer plan is split at
  the exit boundaries (``ServingModel.stage_fns``).  Each round runs ONE
  segment on a batch padded to the tile geometry
  (``kernels/tiling.batch_slots``); samples whose exit confidence clears
  the threshold complete immediately, surviving slots are *compacted*
  (gathered dense) into the next segment's pending buffer, and the freed
  slots are backfilled from the queue before the next stage-1 round.  On
  the int8-resident export the carry between segments is an int8
  :class:`~repro.core.export.QAct` — the inter-stage traffic the E pass
  actually leaves alive.

The replica-pool scheduler (serving/replica.py) subclasses the continuous
scheduler: same pending buffers and landing logic, event-driven over N
elastic replicas with straggler de-prioritization and chaos-tested
failover.

SLO mode (``slo=SLOPolicy(...)``, serving/slo.py): requests with a
``deadline`` are rejected at admission when their budget cannot cover the
queue ahead of them, urgent partial batches override wait-to-fill, and a
survivor whose budget can no longer cover its next segment is
force-completed NOW from its stored exit-head logits (a *degraded*
completion) — every SLO decision is made before the clock advances, so an
admitted request is degraded or completes on time, never silently late.

Bit-exactness contract: slots are independent at fixed batch geometry
(convs, matmuls, GroupNorm, softmax are all per-sample at fixed B), so on
a *resident* export every request's answer is bit-exact vs the monolithic
``fn_exits`` on that request alone at the same slot geometry — regardless
of which requests shared its batches.  The dynamic-scale export computes
per-batch activation abs-max scales, so its answers depend on slot
composition; the scheduler still runs it, but the bit-exactness guarantee
(and the CI smoke assertion) applies to resident exports.  A *degraded*
completion's logits are still bit-exact — they are the head's own row
from a normally-executed segment; only the exit DECISION was forced.

Time: the scheduler advances a single-executor clock.  ``stage_costs``
injects measured per-segment batch costs (the benchmark's simulated clock
— medians, so a noisy box cannot corrupt the A/B); ``stage_costs=None``
uses real wall time per executed batch.  Arrival timestamps gate
admission either way, so a Poisson trace replays faithfully.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.export import exit_confidence
from repro.kernels.tiling import batch_slots
from repro.obs.trace import as_tracer
from repro.serving.metrics import ServingMetrics
from repro.serving.request import Completion, RequestQueue


def exit_decisions(logits, exits, threshold):
    """Per-sample ``(exit_stage, answer_logits)`` arrays — the scheduler-side
    mirror of :func:`repro.core.export.early_exit_batch` (earliest exit
    whose :func:`~repro.core.export.exit_confidence` strictly clears
    ``threshold`` wins; -1 means the final head answers).  The decision
    rule is the shared ``exit_confidence`` — no second copy to drift."""
    stage = np.full(logits.shape[0], -1, np.int64)
    ans = np.array(logits, np.float32, copy=True)
    taken = np.zeros(logits.shape[0], bool)
    for s in sorted(exits):
        take = (np.asarray(exit_confidence(exits[s])) > threshold) & ~taken
        ans[take] = np.asarray(exits[s], np.float32)[take]
        stage[take] = s
        taken |= take
    return stage, ans


def _gather_rows(sources, slots):
    """Assemble a batch padded to exactly ``slots`` from per-sample
    ``(src, idx)`` references — ``idx=None`` means ``src`` IS the sample
    (a fresh request's x), otherwise ``src`` is a batch pytree (array or
    QAct) and ``idx`` a row in it.  Consecutive rows of the same source
    batch (one round's compacted survivors) gather with ONE indexed take
    per pytree leaf instead of O(slots) per-row slices.  The fixed
    geometry keeps one compiled program per stage and slot results
    independent of occupancy."""
    groups = []                          # (src, [idx...]) runs, or (row,)
    for src, idx in sources:
        if idx is None:
            groups.append((src, None))
        elif groups and groups[-1][1] is not None \
                and groups[-1][0] is src:
            groups[-1][1].append(idx)
        else:
            groups.append((src, [idx]))
    parts = []
    for src, idxs in groups:
        if idxs is None:
            parts.append(jax.tree.map(lambda a: a[None], src))
        else:
            arr = jnp.asarray(idxs)
            parts.append(jax.tree.map(lambda a: a[arr], src))
    batch = (parts[0] if len(parts) == 1
             else jax.tree.map(lambda *ps: jnp.concatenate(ps), *parts))
    return jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.zeros((slots - a.shape[0],) + a.shape[1:], a.dtype)])
        if a.shape[0] < slots else a,
        batch)


class _Clock:
    """Single-executor clock: simulated per-stage costs, or wall time."""

    def __init__(self, stage_costs=None):
        self.costs = stage_costs

    def charge(self, stage_idx, fn):
        """Run ``fn`` (returns materialized outputs), return its cost."""
        if self.costs is not None:
            fn()
            return float(self.costs[stage_idx])
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0


class ContinuousBatchScheduler:
    """Continuous-batching scheduler with early-exit slot compaction.

    ``model`` must be exported with exit heads (``stage_fns`` present);
    see the module docstring for the resident-export bit-exactness
    contract.  ``slots`` is padded up to the tile geometry and stays fixed
    for the scheduler's lifetime.  ``threshold=None`` uses the chain's
    calibrated operating point (``model.exit_threshold``).  ``slo`` (an
    :class:`~repro.serving.slo.SLOPolicy`) enables deadline admission and
    graceful degradation; its cost estimates are seeded from
    ``stage_costs`` when given, else learned online from wall time.

    Pending-buffer entries are ``(req, src, idx, head_stage, head_row)``:
    ``(src, idx)`` reference the request's carry row in its last segment's
    output batch, ``(head_stage, head_row)`` hold the exit head it last
    declined — the logits the SLO layer force-completes from when the
    budget runs out (None for segment 0, which has no head yet).
    """

    def __init__(self, model, *, slots=32, threshold=None, stage_costs=None,
                 max_wait=None, slo=None, tracer=None):
        if not model.stage_fns:
            raise ValueError(
                'model has no stage-split plan (exported without exit '
                'heads); the continuous scheduler needs exit boundaries '
                'to compact at')
        self.model = model
        self.slots = batch_slots(slots)
        self.threshold = (model.exit_threshold if threshold is None
                          else threshold)
        self.max_wait = max_wait
        self.n_segs = model.n_stages
        if stage_costs is not None and len(stage_costs) != self.n_segs:
            raise ValueError(f'stage_costs must have {self.n_segs} entries')
        self.slo = slo
        if slo is not None and slo.stage_costs is None:
            if stage_costs is not None:
                slo.seed(stage_costs)
            else:
                slo.stage_costs = [None] * self.n_segs   # learn online
        self._clock = _Clock(stage_costs)
        self.tracer = as_tracer(tracer)
        self._track = 'executor0'          # the single-executor track

    # ---- scheduling policy: deepest full batch first, wait to fill when
    # arrivals are still coming, drain partial batches once they are not.
    # ``max_wait`` bounds request aging under light load: a partial batch
    # runs once its oldest request has waited that long.
    def _pick(self, pend, more_arrivals, now):
        for k in reversed(range(self.n_segs)):
            if len(pend[k]) >= self.slots:
                return k
        if more_arrivals:
            if self.max_wait is not None:
                for k in reversed(range(self.n_segs)):
                    if pend[k] and now - pend[k][0][0].t_arrival \
                            >= self.max_wait:
                        return k              # aged out: run partial
            return None                       # wait for the queue to fill
        for k in reversed(range(self.n_segs)):
            if pend[k]:
                return k                      # drain
        return None

    # --------------------------------------------------------- completions

    def _complete(self, req, logits_row, stage, now, completions, metrics,
                  degraded=False):
        c = Completion(rid=req.rid, logits=logits_row,
                       pred=int(logits_row.argmax()), exit_stage=stage,
                       t_arrival=req.t_arrival, t_done=now,
                       t_start=req.t_start, deadline=req.deadline,
                       degraded=degraded)
        completions[req.rid] = c
        metrics.record_completion(c)

    def _land(self, k, items, out, now, pend, completions, metrics,
              track=None):
        """Process segment ``k``'s output: complete confident exits,
        promote survivors (carry reference + their declined head's logits)
        to ``pend[k + 1]``.  Shared with the replica pool, which lands
        flights asynchronously."""
        if k < self.n_segs - 1:
            exits, carry = out
            s = self.model.stage_exits[k]
            conf = np.asarray(exit_confidence(exits[s]))
            head = np.asarray(exits[s], np.float32)
            n_exit = 0
            for i, (req, *_) in enumerate(items):
                if conf[i] > self.threshold:
                    n_exit += 1
                    self._complete(req, head[i], s, now, completions,
                                   metrics)
                else:                         # compact: reference the row
                    pend[k + 1].append((req, carry, i, s, head[i]))
            if self.tracer.enabled:
                self.tracer.instant(
                    'compaction', now, track=track or self._track,
                    stage=k, n_exit=n_exit, n_survive=len(items) - n_exit)
        else:
            logits = np.asarray(out, np.float32)
            for i, (req, *_) in enumerate(items):
                self._complete(req, logits[i], -1, now, completions,
                               metrics)

    def _trace_dispatch(self, items, now):
        """Close each request's queue span: the wait ends NOW (the span
        opened at arrival, or at the requeue after a failover kill)."""
        for req, *_ in items:
            t0 = (req.t_arrival if req.t_enqueued is None
                  else req.t_enqueued)
            self.tracer.async_span(
                'request.queue', t0, now,
                track=f'cohort{req.rid // self.slots}', cid=req.rid,
                rid=req.rid, requeued=req.t_enqueued is not None)

    def _run_segment(self, k, pend, completions, metrics, now):
        items = [pend[k].popleft()
                 for _ in range(min(len(pend[k]), self.slots))]
        if k == 0:
            for req, *_ in items:
                req.t_start = now             # service starts; wait ends
            if self.tracer.enabled:
                self._trace_dispatch(items, now)
        batch = _gather_rows([(src, idx) for _, src, idx, *_ in items],
                             self.slots)
        out = []

        def execute():
            out.append(jax.block_until_ready(
                self.model.run_stage(k, batch)))
        cost = self._clock.charge(k, execute)
        if self.tracer.enabled:
            self.tracer.add(
                'stage.exec', now, now + cost, track=self._track, stage=k,
                live=len(items), slots=self.slots,
                rids=[r.rid for r, *_ in items])
        now += cost
        if self.slo is not None:
            self.slo.observe(k, cost)
        metrics.record_batch(k, len(items), self.slots, t=now - cost,
                             cost=cost)
        self._land(k, items, out[0], now, pend, completions, metrics)
        return now

    # ------------------------------------------------------------ SLO hooks

    def _admit(self, r, now, pend, metrics) -> bool:
        if self.slo is None or r.deadline is None:
            return True
        ok, budget, need = self.slo.admit_explain(r.deadline, now,
                                                  len(pend[0]), self.slots)
        if ok:
            return True
        self.slo.n_rejected += 1
        metrics.record_rejection(r.rid, now, 'admission',
                                 t_arrival=r.t_arrival)
        if self.tracer.enabled:
            self.tracer.instant('request.admit', now, track='scheduler',
                                rid=r.rid, admitted=False,
                                reason='admission',
                                budget_s=round(budget, 6),
                                need_s=round(need, 6))
        return False

    def _slo_degrade(self, pend, k_star, now, completions, metrics):
        """Before charging segment ``k_star`` (cost ``c``): any pending
        deadline that cannot survive the charge is resolved NOW — degraded
        to its stored head logits (segments >= 1), or rejected (segment 0,
        no head yet; admission margins make this rare).  Runs at ``now``,
        before time advances, so the resolution itself is never late."""
        c = self.slo._cost(k_star)
        for j, buf in enumerate(pend):
            kept, pos = deque(), 0
            for item in buf:
                req = item[0]
                if req.deadline is None:
                    kept.append(item)
                    pos += 1
                    continue
                in_batch = j == k_star and pos < self.slots
                if self.slo.affordable(req.deadline, now, j, c, in_batch):
                    kept.append(item)
                    pos += 1
                elif j == 0:
                    self.slo.n_rejected += 1
                    metrics.record_rejection(req.rid, now, 'missed',
                                             t_arrival=req.t_arrival)
                    if self.tracer.enabled:
                        self.tracer.instant('request.admit', now,
                                            track='scheduler', rid=req.rid,
                                            admitted=False, reason='missed')
                else:
                    self.slo.n_degraded += 1
                    self._complete(req, item[4], item[3], now, completions,
                                   metrics, degraded=True)
            buf.clear()
            buf.extend(kept)

    def run_trace(self, requests):
        """Serve a whole arrival trace; returns ``({rid: Completion},
        ServingMetrics)``.  Terminates exactly when every request has
        completed or been rejected (the queue and every stage buffer
        drained)."""
        queue = RequestQueue(requests)
        pend = [deque() for _ in range(self.n_segs)]
        completions, metrics = {}, ServingMetrics()
        now = queue.next_arrival() or 0.0
        last_depth = None
        while queue or any(pend):
            for r in queue.pop_ready(now, self.slots - len(pend[0])):
                if self._admit(r, now, pend, metrics):
                    pend[0].append((r, r.x, None, None, None))
            depth = len(pend[0]) + queue.n_ready(now)
            if depth != last_depth:
                metrics.record_gauge('queue_depth', now, depth)
                last_depth = depth
            k = self._pick(pend, more_arrivals=bool(queue), now=now)
            if self.slo is not None:
                urgent = self.slo.urgent_segment(pend, now)
                if urgent is not None:
                    k = urgent                # deadline overrides fill
            if k is None:
                horizons = [t for t in (queue.next_arrival(),)
                            if t is not None]
                if self.max_wait is not None and any(pend):
                    oldest = min(p[0][0].t_arrival for p in pend if p)
                    horizons.append(oldest + self.max_wait)
                if self.slo is not None:
                    wake = self.slo.wake(pend, now)
                    if wake is not None:
                        horizons.append(wake)
                if not horizons:   # everything left was rejected this round
                    continue
                now = max(now, min(horizons))
                continue
            if self.slo is not None:
                self._slo_degrade(pend, k, now, completions, metrics)
                if not pend[k]:               # the sweep emptied the batch
                    continue
            now = self._run_segment(k, pend, completions, metrics, now)
        return completions, metrics


class StaticBatchScheduler:
    """The baseline: full batches through the monolithic ``fn_exits``.

    Early exits are applied to the *results* (same decision rule as the
    compacting scheduler, so answers agree bit-exactly on a resident
    export) but every slot pays full depth — the compute the E pass saved
    is given back at serve time.  ``batch_cost`` injects the measured
    monolithic batch cost for the simulated clock (None = wall time).
    """

    def __init__(self, model, *, slots=32, threshold=None, batch_cost=None,
                 tracer=None):
        if model.fn_exits is None:
            raise ValueError('model was exported without exit heads')
        self.model = model
        self.slots = batch_slots(slots)
        self.threshold = (model.exit_threshold if threshold is None
                          else threshold)
        self._clock = _Clock(None if batch_cost is None else [batch_cost])
        self.tracer = as_tracer(tracer)
        self._track = 'executor0'

    def run_trace(self, requests):
        queue = RequestQueue(requests)
        completions, metrics = {}, ServingMetrics()
        now = queue.next_arrival() or 0.0
        while queue:
            ready = queue.pop_ready(now, self.slots)
            while len(ready) < self.slots and queue:   # wait to fill
                now = max(now, queue.next_arrival())
                ready += queue.pop_ready(now, self.slots - len(ready))
            for req in ready:
                req.t_start = now
                if self.tracer.enabled:
                    self.tracer.async_span(
                        'request.queue', req.t_arrival, now,
                        track=f'cohort{req.rid // self.slots}',
                        cid=req.rid, rid=req.rid)
            batch = _gather_rows([(r.x, None) for r in ready], self.slots)
            out = []

            def execute():
                out.append(jax.block_until_ready(
                    self.model.fn_exits(self.model.params, batch)))
            cost = self._clock.charge(0, execute)
            if self.tracer.enabled:
                self.tracer.add('stage.exec', now, now + cost,
                                track=self._track, stage=0,
                                live=len(ready), slots=self.slots,
                                rids=[r.rid for r in ready])
            now += cost
            metrics.record_batch(0, len(ready), self.slots, t=now - cost,
                                 cost=cost)
            logits, exits = out[0]
            stage, ans = exit_decisions(logits, exits, self.threshold)
            for i, req in enumerate(ready):
                c = Completion(rid=req.rid, logits=ans[i],
                               pred=int(ans[i].argmax()),
                               exit_stage=int(stage[i]),
                               t_arrival=req.t_arrival, t_done=now,
                               t_start=req.t_start, deadline=req.deadline)
                completions[req.rid] = c
                metrics.record_completion(c)
        return completions, metrics
