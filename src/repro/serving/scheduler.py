"""Request-level schedulers: continuous batching with early-exit compaction.

Two schedulers share one contract (``run_trace(requests) -> (completions,
metrics)``) so the load benchmark can A/B them on the same arrival trace:

* :class:`StaticBatchScheduler` — the pre-PR-4 deployment: fill a batch
  from the queue, run the monolithic ``fn_exits`` to FULL depth, apply the
  early-exit rule afterwards.  Exits change which head answers but save no
  compute: one hard sample holds every exited slot hostage to full depth.

* :class:`ContinuousBatchScheduler` — the tentpole: the model's layer plan
  is split at the exit boundaries (``ServingModel.stage_fns``).  Each
  round runs ONE segment on a batch padded to the tile geometry
  (``kernels/tiling.batch_slots``); samples whose exit confidence clears
  the threshold complete immediately, surviving slots are *compacted*
  (gathered dense) into the next segment's pending buffer, and the freed
  slots are backfilled from the queue before the next stage-1 round.  On
  the int8-resident export the carry between segments is an int8
  :class:`~repro.core.export.QAct` — the inter-stage traffic the E pass
  actually leaves alive.

Bit-exactness contract: slots are independent at fixed batch geometry
(convs, matmuls, GroupNorm, softmax are all per-sample at fixed B), so on
a *resident* export every request's answer is bit-exact vs the monolithic
``fn_exits`` on that request alone at the same slot geometry — regardless
of which requests shared its batches.  The dynamic-scale export computes
per-batch activation abs-max scales, so its answers depend on slot
composition; the scheduler still runs it, but the bit-exactness guarantee
(and the CI smoke assertion) applies to resident exports.

Time: the scheduler advances a single-executor clock.  ``stage_costs``
injects measured per-segment batch costs (the benchmark's simulated clock
— medians, so a noisy box cannot corrupt the A/B); ``stage_costs=None``
uses real wall time per executed batch.  Arrival timestamps gate
admission either way, so a Poisson trace replays faithfully.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.export import exit_confidence
from repro.kernels.tiling import batch_slots
from repro.serving.metrics import ServingMetrics
from repro.serving.request import Completion, RequestQueue


def exit_decisions(logits, exits, threshold):
    """Per-sample ``(exit_stage, answer_logits)`` arrays — the scheduler-side
    mirror of :func:`repro.core.export.early_exit_batch` (earliest exit
    whose :func:`~repro.core.export.exit_confidence` strictly clears
    ``threshold`` wins; -1 means the final head answers).  The decision
    rule is the shared ``exit_confidence`` — no second copy to drift."""
    stage = np.full(logits.shape[0], -1, np.int64)
    ans = np.array(logits, np.float32, copy=True)
    taken = np.zeros(logits.shape[0], bool)
    for s in sorted(exits):
        take = (np.asarray(exit_confidence(exits[s])) > threshold) & ~taken
        ans[take] = np.asarray(exits[s], np.float32)[take]
        stage[take] = s
        taken |= take
    return stage, ans


def _gather_rows(sources, slots):
    """Assemble a batch padded to exactly ``slots`` from per-sample
    ``(src, idx)`` references — ``idx=None`` means ``src`` IS the sample
    (a fresh request's x), otherwise ``src`` is a batch pytree (array or
    QAct) and ``idx`` a row in it.  Consecutive rows of the same source
    batch (one round's compacted survivors) gather with ONE indexed take
    per pytree leaf instead of O(slots) per-row slices.  The fixed
    geometry keeps one compiled program per stage and slot results
    independent of occupancy."""
    groups = []                          # (src, [idx...]) runs, or (row,)
    for src, idx in sources:
        if idx is None:
            groups.append((src, None))
        elif groups and groups[-1][1] is not None \
                and groups[-1][0] is src:
            groups[-1][1].append(idx)
        else:
            groups.append((src, [idx]))
    parts = []
    for src, idxs in groups:
        if idxs is None:
            parts.append(jax.tree.map(lambda a: a[None], src))
        else:
            arr = jnp.asarray(idxs)
            parts.append(jax.tree.map(lambda a: a[arr], src))
    batch = (parts[0] if len(parts) == 1
             else jax.tree.map(lambda *ps: jnp.concatenate(ps), *parts))
    return jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.zeros((slots - a.shape[0],) + a.shape[1:], a.dtype)])
        if a.shape[0] < slots else a,
        batch)


class _Clock:
    """Single-executor clock: simulated per-stage costs, or wall time."""

    def __init__(self, stage_costs=None):
        self.costs = stage_costs

    def charge(self, stage_idx, fn):
        """Run ``fn`` (returns materialized outputs), return its cost."""
        if self.costs is not None:
            fn()
            return float(self.costs[stage_idx])
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0


class ContinuousBatchScheduler:
    """Continuous-batching scheduler with early-exit slot compaction.

    ``model`` must be exported with exit heads (``stage_fns`` present);
    see the module docstring for the resident-export bit-exactness
    contract.  ``slots`` is padded up to the tile geometry and stays fixed
    for the scheduler's lifetime.  ``threshold=None`` uses the chain's
    calibrated operating point (``model.exit_threshold``).
    """

    def __init__(self, model, *, slots=32, threshold=None, stage_costs=None,
                 max_wait=None):
        if not model.stage_fns:
            raise ValueError(
                'model has no stage-split plan (exported without exit '
                'heads); the continuous scheduler needs exit boundaries '
                'to compact at')
        self.model = model
        self.slots = batch_slots(slots)
        self.threshold = (model.exit_threshold if threshold is None
                          else threshold)
        self.max_wait = max_wait
        self.n_segs = model.n_stages
        if stage_costs is not None and len(stage_costs) != self.n_segs:
            raise ValueError(f'stage_costs must have {self.n_segs} entries')
        self._clock = _Clock(stage_costs)

    # ---- scheduling policy: deepest full batch first, wait to fill when
    # arrivals are still coming, drain partial batches once they are not.
    # ``max_wait`` bounds request aging under light load: a partial batch
    # runs once its oldest request has waited that long.
    def _pick(self, pend, more_arrivals, now):
        for k in reversed(range(self.n_segs)):
            if len(pend[k]) >= self.slots:
                return k
        if more_arrivals:
            if self.max_wait is not None:
                for k in reversed(range(self.n_segs)):
                    if pend[k] and now - pend[k][0][0].t_arrival \
                            >= self.max_wait:
                        return k              # aged out: run partial
            return None                       # wait for the queue to fill
        for k in reversed(range(self.n_segs)):
            if pend[k]:
                return k                      # drain
        return None

    def _run_segment(self, k, pend, completions, metrics, now):
        items = [pend[k].popleft()
                 for _ in range(min(len(pend[k]), self.slots))]
        batch = _gather_rows([(src, idx) for _, src, idx in items],
                             self.slots)
        out = []

        def execute():
            out.append(jax.block_until_ready(
                self.model.run_stage(k, batch)))
        now += self._clock.charge(k, execute)
        metrics.record_batch(k, len(items), self.slots)

        if k < self.n_segs - 1:
            exits, carry = out[0]
            s = self.model.stage_exits[k]
            conf = np.asarray(exit_confidence(exits[s]))
            head = np.asarray(exits[s], np.float32)
            for i, (req, _, _) in enumerate(items):
                if conf[i] > self.threshold:
                    c = Completion(rid=req.rid, logits=head[i],
                                   pred=int(head[i].argmax()), exit_stage=s,
                                   t_arrival=req.t_arrival, t_done=now)
                    completions[req.rid] = c
                    metrics.record_completion(c)
                else:                         # compact: reference the row
                    pend[k + 1].append((req, carry, i))
        else:
            logits = np.asarray(out[0], np.float32)
            for i, (req, _, _) in enumerate(items):
                c = Completion(rid=req.rid, logits=logits[i],
                               pred=int(logits[i].argmax()), exit_stage=-1,
                               t_arrival=req.t_arrival, t_done=now)
                completions[req.rid] = c
                metrics.record_completion(c)
        return now

    def run_trace(self, requests):
        """Serve a whole arrival trace; returns ``({rid: Completion},
        ServingMetrics)``.  Terminates exactly when every request has
        completed (the queue and every stage buffer drained)."""
        queue = RequestQueue(requests)
        pend = [deque() for _ in range(self.n_segs)]
        completions, metrics = {}, ServingMetrics()
        now = queue.next_arrival() or 0.0
        while queue or any(pend):
            for r in queue.pop_ready(now, self.slots - len(pend[0])):
                pend[0].append((r, r.x, None))
            k = self._pick(pend, more_arrivals=bool(queue), now=now)
            if k is None:
                nxt = queue.next_arrival()
                if self.max_wait is not None and any(pend):
                    oldest = min(p[0][0].t_arrival for p in pend if p)
                    nxt = min(nxt, oldest + self.max_wait)
                now = max(now, nxt)
                continue
            now = self._run_segment(k, pend, completions, metrics, now)
        return completions, metrics


class StaticBatchScheduler:
    """The baseline: full batches through the monolithic ``fn_exits``.

    Early exits are applied to the *results* (same decision rule as the
    compacting scheduler, so answers agree bit-exactly on a resident
    export) but every slot pays full depth — the compute the E pass saved
    is given back at serve time.  ``batch_cost`` injects the measured
    monolithic batch cost for the simulated clock (None = wall time).
    """

    def __init__(self, model, *, slots=32, threshold=None, batch_cost=None):
        if model.fn_exits is None:
            raise ValueError('model was exported without exit heads')
        self.model = model
        self.slots = batch_slots(slots)
        self.threshold = (model.exit_threshold if threshold is None
                          else threshold)
        self._clock = _Clock(None if batch_cost is None else [batch_cost])

    def run_trace(self, requests):
        queue = RequestQueue(requests)
        completions, metrics = {}, ServingMetrics()
        now = queue.next_arrival() or 0.0
        while queue:
            ready = queue.pop_ready(now, self.slots)
            while len(ready) < self.slots and queue:   # wait to fill
                now = max(now, queue.next_arrival())
                ready += queue.pop_ready(now, self.slots - len(ready))
            batch = _gather_rows([(r.x, None) for r in ready], self.slots)
            out = []

            def execute():
                out.append(jax.block_until_ready(
                    self.model.fn_exits(self.model.params, batch)))
            now += self._clock.charge(0, execute)
            metrics.record_batch(0, len(ready), self.slots)
            logits, exits = out[0]
            stage, ans = exit_decisions(logits, exits, self.threshold)
            for i, req in enumerate(ready):
                c = Completion(rid=req.rid, logits=ans[i],
                               pred=int(ans[i].argmax()),
                               exit_stage=int(stage[i]),
                               t_arrival=req.t_arrival, t_done=now)
                completions[req.rid] = c
                metrics.record_completion(c)
        return completions, metrics
