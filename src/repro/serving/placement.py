"""Pipeline-parallel placement: pack model stages onto devices, serve them.

Two layers live here:

1. **The placement solver** (:func:`solve_placement`) — a pure function
   from measured per-stage batch costs (``BENCH_load.json``-style
   ``stage_costs_us`` calibration) and a device count to a
   :class:`Placement`: every ``(model, stage)`` pair of every chain gets
   exactly one device.  The baseline is greedy **LPT** (longest
   processing time first): stages sorted by cost descending, each
   assigned to the least-loaded device.  The report carries the classic
   guarantees alongside the achieved loads:

   * ``guarantee`` — the sound greedy bound ``total/M + c_max``: the
     achieved ``max_load`` NEVER exceeds it (asserted by the property
     sweep in tests/test_placement_property.py);
   * ``opt_lower`` — a lower bound on the optimal makespan,
     ``max(total/M, c_max, c_(M) + c_(M+1))`` (some device must run two
     of the M+1 largest stages);
   * ``bound`` — ``(4/3 - 1/(3M)) * opt_lower``, the LPT competitive
     ratio applied to the OPT lower bound; ``balance = max_load /
     opt_lower`` then brackets how far from optimal the packing can be.

   N registered chains pack onto M devices through the same call —
   ``ModelRegistry.plan_placement`` feeds it every model's measured
   costs at its own slot geometry.

2. **The pipeline-parallel scheduler**
   (:class:`PipelineParallelScheduler`) — the continuous-batching
   scheduler's pending-buffer/landing machinery run event-driven over M
   real jax devices (CPU: ``XLA_FLAGS
   =--xla_force_host_platform_device_count=8``): stage *k* executes on
   its placed device (``ServingModel.place_stages`` commits a params
   copy per device, so jit runs where the committed operands live), and
   the int8 :class:`~repro.core.export.QAct` carry streams between
   devices with ``jax.device_put`` at every cross-device stage boundary
   — each such hop is a ``transfer.carry`` span on the destination
   device's trace track, charged ``transfer_frac`` of the consuming
   stage's cost on the simulated clock.

   **Never-idle dispatch rule**: a device with pending work for any of
   its stages never waits — a device finishing stage *k* for cohort A
   immediately starts stage *k* for cohort B (deepest assigned stage
   first).  The single exception is stage 0, which may wait to fill a
   batch while arrivals are still coming (``max_wait`` bounds the
   aging), exactly like the single-device scheduler.

   ``compact=True`` is the continuous mode: survivors from any cohort
   merge into the next stage's pending buffer (freed slots backfill).
   ``compact=False`` is the static-cohort mode: a batch formed at stage
   0 travels as a unit — exited rows complete but their slots ride
   empty, never backfilled (the A/B that shows what compaction buys in
   *device time*, not just batch slots).

   Chaos: a :class:`~repro.serving.replica.ChaosPlan` kills a *device*
   at a seeded time — its in-flight batch is discarded and the items
   requeue (segment-0 by original arrival through
   ``RequestQueue.requeue``, deeper ones at the front of their pending
   buffer with their carry intact), the device leaves the pool, and the
   placement is re-solved over the survivors (deterministic: same
   solver, same seed).  Slot independence at fixed geometry makes every
   completion bit-exact vs the monolithic single-device ``fn_exits``
   path no matter how requests were cohorted, transferred, or requeued
   — the differential suite (tests/test_pipeline_parallel.py) asserts
   it under 8 forced host devices.

Like the replica pool, the scheduler runs on the **simulated clock only**
(``stage_costs`` required): one host process cannot execute M devices
concurrently for real, but it can execute their batches eagerly and
order landings by simulated event time — which also makes chaos runs
deterministic.
"""
from __future__ import annotations

import math
import random
from collections import deque
from collections.abc import Mapping
from dataclasses import dataclass
from functools import cached_property

import jax
import numpy as np

from repro.launch.mesh import data_axes
from repro.serving.metrics import ServingMetrics
from repro.serving.replica import ChaosPlan
from repro.serving.request import RequestQueue
from repro.serving.scheduler import ContinuousBatchScheduler, _gather_rows

#: key used when ``solve_placement`` is handed a bare cost sequence
DEFAULT_MODEL = 'model'


def lpt_ratio(n_devices: int) -> float:
    """LPT's competitive ratio on ``n_devices`` identical machines:
    ``max_load <= (4/3 - 1/(3M)) * OPT`` (Graham 1969)."""
    return 4.0 / 3.0 - 1.0 / (3.0 * n_devices)


def pipeline_devices(mesh=None) -> tuple:
    """The device list serving placement packs onto.

    ``mesh=None`` -> all local devices (``jax.devices()``).  Given a
    mesh (``launch/mesh.py``), pipeline stages are placed along its
    *data* axes only — the 'model' axis is reserved for intra-stage
    sharding, so we take the model-index-0 slice and flatten the rest
    (``data_axes`` order).  ``make_local_mesh()`` thus yields the single
    local device, and a ``(4, 2)`` (data, model) mesh yields 4 pipeline
    targets.
    """
    if mesh is None:
        return tuple(jax.devices())
    arr = np.asarray(mesh.devices)
    keep = data_axes(mesh)
    for i in reversed(range(len(mesh.axis_names))):
        if mesh.axis_names[i] not in keep:
            arr = np.take(arr, 0, axis=i)
    return tuple(arr.reshape(-1))


@dataclass(frozen=True)
class Placement:
    """One solved packing of ``(model, stage)`` pairs onto devices.

    ``assignment`` is a sorted tuple of ``((model, stage), device)``;
    ``loads[d]`` is device ``d``'s summed stage cost.  See the module
    docstring for the ``guarantee`` / ``opt_lower`` / ``bound``
    semantics."""
    n_devices: int
    assignment: tuple
    loads: tuple
    opt_lower: float
    guarantee: float
    bound: float

    @cached_property
    def _by_key(self) -> dict:
        return dict(self.assignment)

    @property
    def max_load(self) -> float:
        return max(self.loads)

    @property
    def balance(self) -> float:
        """``max_load / opt_lower`` — 1.0 means provably optimal."""
        return self.max_load / self.opt_lower if self.opt_lower > 0 else 1.0

    def device_of(self, stage: int, model: str = DEFAULT_MODEL) -> int:
        return self._by_key[(model, stage)]

    def stages_on(self, device: int) -> tuple:
        """Sorted ``(model, stage)`` pairs assigned to ``device``."""
        return tuple(k for k, d in self.assignment if d == device)

    def summary(self) -> dict:
        return {
            'n_devices': self.n_devices,
            'assignment': {f'{m}:{k}': d for (m, k), d in self.assignment},
            'loads': [round(v, 6) for v in self.loads],
            'max_load': round(self.max_load, 6),
            'opt_lower': round(self.opt_lower, 6),
            'lpt_ratio': round(lpt_ratio(self.n_devices), 6),
            'bound': round(self.bound, 6),
            'guarantee': round(self.guarantee, 6),
            'balance': round(self.balance, 4),
        }


def solve_placement(stage_costs, n_devices: int, *, seed: int = 0
                    ) -> Placement:
    """Greedy-LPT packing of every model's stages onto ``n_devices``.

    ``stage_costs`` is a per-stage cost sequence for one model, or a
    ``{model_name: costs}`` mapping for N models (the multi-model
    registry path).  Costs are unit-free (us, s — whatever the
    calibration measured); they only need to share a unit.  Ties between
    equal-cost stages break by a ``seed``-keyed shuffle, so the solver
    is a pure function of ``(stage_costs, n_devices, seed)`` — re-solved
    placements (e.g. after a device kill) are reproducible.

    Degenerate inputs are fine: one device (everything lands on it),
    more stages than devices (devices hold several stages), zero-cost
    stages (placed like any other).  Negative or non-finite costs and an
    empty stage list are errors.
    """
    if n_devices < 1:
        raise ValueError(f'need at least one device, got {n_devices}')
    if isinstance(stage_costs, Mapping):
        costs = {str(m): tuple(float(c) for c in cs)
                 for m, cs in stage_costs.items()}
    else:
        costs = {DEFAULT_MODEL: tuple(float(c) for c in stage_costs)}
    if not costs or any(not cs for cs in costs.values()):
        raise ValueError('every model needs at least one stage cost')
    for m, cs in costs.items():
        bad = [c for c in cs if c < 0 or not math.isfinite(c)]
        if bad:
            raise ValueError(f'model {m!r}: stage costs must be finite '
                             f'and >= 0, got {bad}')
    items = [(m, k, c) for m, cs in sorted(costs.items())
             for k, c in enumerate(cs)]
    rng = random.Random(seed)
    tie = [rng.random() for _ in items]
    order = sorted(range(len(items)),
                   key=lambda i: (-items[i][2], tie[i]))
    loads = [0.0] * n_devices
    assign = {}
    for i in order:
        m, k, c = items[i]
        d = min(range(n_devices), key=lambda j: (loads[j], j))
        assign[(m, k)] = d
        loads[d] += c
    total = sum(c for _, _, c in items)
    cs_desc = sorted((c for _, _, c in items), reverse=True)
    opt_lower = max(total / n_devices, cs_desc[0])
    if len(cs_desc) > n_devices:
        opt_lower = max(opt_lower,
                        cs_desc[n_devices - 1] + cs_desc[n_devices])
    return Placement(
        n_devices=n_devices,
        assignment=tuple(sorted(assign.items())),
        loads=tuple(loads),
        opt_lower=opt_lower,
        guarantee=total / n_devices + (cs_desc[0] if cs_desc else 0.0),
        bound=lpt_ratio(n_devices) * opt_lower)


@dataclass
class _Flight:
    """One dispatched segment batch on a device: executed eagerly at
    dispatch, lands at ``t_end`` on the simulated clock — unless a kill
    fires first (``t_kill``), in which case the output is discarded and
    the items requeue.  ``t_exec`` is when execution starts: dispatch
    time plus the carry-transfer charge (``src_devs`` nonempty)."""
    seq: int
    dev: int
    k: int
    items: list
    out: object
    t_dispatch: float
    t_exec: float
    t_end: float
    src_devs: tuple = ()
    nbytes: int = 0
    t_kill: float | None = None

    @property
    def t_land(self) -> float:
        return self.t_end if self.t_kill is None else self.t_kill


class PipelineParallelScheduler(ContinuousBatchScheduler):
    """See the module docstring.  Inherits the pending-buffer layout,
    exit rule, and landing logic from
    :class:`~repro.serving.scheduler.ContinuousBatchScheduler`; runs
    them event-driven over the placed devices."""

    def __init__(self, model, *, slots=32, threshold=None, stage_costs=None,
                 devices=None, placement=None, name=DEFAULT_MODEL,
                 compact=True, max_wait=None, chaos=None,
                 transfer_frac=0.02, seed=0, tracer=None):
        if stage_costs is None:
            raise ValueError(
                'PipelineParallelScheduler needs stage_costs: placement '
                'is cost-based and the pipeline is event-driven on the '
                'simulated clock (one host process cannot run M devices '
                'concurrently for real)')
        super().__init__(model, slots=slots, threshold=threshold,
                         stage_costs=stage_costs, max_wait=max_wait,
                         tracer=tracer)
        self.stage_costs = [float(c) for c in stage_costs]
        self.jax_devices = (tuple(devices) if devices is not None
                            else pipeline_devices())
        if not self.jax_devices:
            raise ValueError('need at least one device')
        if placement is not None \
                and placement.n_devices != len(self.jax_devices):
            raise ValueError(
                f'placement solved for {placement.n_devices} devices, '
                f'got {len(self.jax_devices)}')
        self._placement0 = placement
        self.name = name
        self.compact = compact
        self.chaos = chaos or ChaosPlan()
        self.transfer_frac = float(transfer_frac)
        self.seed = seed
        self.base_model = model
        self.alive = list(range(len(self.jax_devices)))
        self.placement = placement
        self._solve_and_place()

    # ------------------------------------------------------ placement ops

    def _solve_and_place(self):
        """(Re-)solve the placement over the alive devices and commit the
        model's stage params to their assigned devices."""
        n = len(self.alive)
        if self.placement is None or self.placement.n_devices != n:
            self.placement = solve_placement({self.name: self.stage_costs},
                                             n, seed=self.seed)
        self.stage_dev = tuple(
            self.alive[self.placement.device_of(k, model=self.name)]
            for k in range(self.n_segs))
        self.model = self.base_model.place_stages(
            tuple(self.jax_devices[d] for d in self.stage_dev))

    def _ordinal_of(self, src):
        """Global device ordinal a carry batch is committed to (None for
        host arrays / uncommitted values)."""
        leaves = jax.tree.leaves(src)
        if not leaves:
            return None
        devs = getattr(leaves[0], 'devices', None)
        if not callable(devs):
            return None
        try:
            (dev,) = devs()
        except (TypeError, ValueError):
            return None
        try:
            return self.jax_devices.index(dev)
        except ValueError:
            return None

    # ----------------------------------------------------------- dispatch

    def _pop_items(self, k, pend):
        """Up to ``slots`` items for one flight.  Static mode keeps
        cohorts intact past stage 0: pop only while the head item shares
        the front cohort (survivor groups are contiguous — they land,
        and requeue after kills, as units)."""
        if self.compact or k == 0:
            return [pend[k].popleft()
                    for _ in range(min(len(pend[k]), self.slots))]
        c0 = self._cohort[pend[k][0][0].rid]
        items = []
        while pend[k] and len(items) < self.slots \
                and self._cohort[pend[k][0][0].rid] == c0:
            items.append(pend[k].popleft())
        return items

    def _pick_dev(self, d, pend, more_arrivals, now):
        """Never-idle rule: the deepest of ``d``'s assigned stages with
        pending work; stage 0 waits to fill while arrivals are still
        coming (``max_wait`` ages partial batches out)."""
        for k in reversed(range(self.n_segs)):
            if self.stage_dev[k] != d:
                continue
            if k > 0:
                if pend[k]:
                    return k
                continue
            if len(pend[0]) >= self.slots:
                return 0
            if pend[0]:
                if not more_arrivals:
                    return 0
                if self.max_wait is not None and \
                        now - pend[0][0][0].t_arrival >= self.max_wait:
                    return 0
        return None

    def _dispatch(self, d, k, pend, metrics, now):
        """Pop a stage-``k`` batch, stream its carry onto device ``d``
        (``jax.device_put`` — the ``transfer.carry`` charge when any
        source sat on another device), execute eagerly, and put the
        result in flight until ``t_exec + cost``."""
        items = self._pop_items(k, pend)
        if k == 0:
            cohort = self._next_cohort
            self._next_cohort += 1
            for req, *_ in items:
                req.t_start = now
                self._cohort[req.rid] = cohort
            if self.tracer.enabled:
                self._trace_dispatch(items, now)
        dev = self.jax_devices[d]
        src_ords = set()
        if k > 0:
            moved, sources = {}, []
            for _, src, idx, *_ in items:
                if id(src) not in moved:
                    o = self._ordinal_of(src)
                    if o is not None and o != d:
                        src_ords.add(o)
                    moved[id(src)] = jax.device_put(src, dev)
                sources.append((moved[id(src)], idx))
            batch = _gather_rows(sources, self.slots)
        else:
            batch = jax.device_put(
                _gather_rows([(src, idx) for _, src, idx, *_ in items],
                             self.slots), dev)
        nbytes = sum(leaf.size * leaf.dtype.itemsize
                     for leaf in jax.tree.leaves(batch))
        out = jax.block_until_ready(self.model.run_stage(k, batch))
        cost = self.stage_costs[k] * self.chaos.slow_factor(d, now)
        t_exec = now + (self.transfer_frac * self.stage_costs[k]
                        if src_ords else 0.0)
        fl = _Flight(seq=self._seq, dev=d, k=k, items=items, out=out,
                     t_dispatch=now, t_exec=t_exec, t_end=t_exec + cost,
                     src_devs=tuple(sorted(src_ords)), nbytes=nbytes)
        self._seq += 1
        self._free_at[d] = fl.t_end
        return fl

    def _land_flight(self, fl, pend, queue, completions, metrics):
        """A flight reaches its land time.  Killed flights requeue their
        requests (carry intact — the re-run is bit-exact); successful
        flights complete/promote exactly like the single-executor path."""
        t = fl.t_land
        track = f'device{fl.dev}'
        if fl.t_kill is not None:
            if self.tracer.enabled:
                t_tr = min(fl.t_kill, fl.t_exec)
                if fl.src_devs and t_tr > fl.t_dispatch:
                    self.tracer.add(
                        'transfer.carry', fl.t_dispatch, t_tr, track=track,
                        stage=fl.k, src_devices=list(fl.src_devs),
                        dst_device=fl.dev, bytes=fl.nbytes,
                        killed=fl.t_kill <= fl.t_exec)
                if fl.t_kill > fl.t_exec:
                    self.tracer.add(
                        'stage.exec', fl.t_exec, fl.t_kill, track=track,
                        stage=fl.k, live=len(fl.items), slots=self.slots,
                        killed=True, rids=[it[0].rid for it in fl.items])
            for item in reversed(fl.items):
                req = item[0]
                if fl.k == 0:
                    req.t_start = None     # service restarts from scratch
                    req.t_enqueued = t     # next queue span opens here
                    queue.requeue(req)
                else:
                    pend[fl.k].appendleft(item)
            return
        if self.tracer.enabled:
            if fl.src_devs and fl.t_exec > fl.t_dispatch:
                self.tracer.add(
                    'transfer.carry', fl.t_dispatch, fl.t_exec, track=track,
                    stage=fl.k, src_devices=list(fl.src_devs),
                    dst_device=fl.dev, bytes=fl.nbytes)
            self.tracer.add(
                'stage.exec', fl.t_exec, fl.t_end, track=track, stage=fl.k,
                live=len(fl.items), slots=self.slots,
                rids=[it[0].rid for it in fl.items])
        metrics.record_batch(fl.k, len(fl.items), self.slots, t=fl.t_exec,
                             cost=fl.t_end - fl.t_exec, device=fl.dev)
        self._land(fl.k, fl.items, fl.out, t, pend, completions, metrics,
                   track=track)

    # --------------------------------------------------------------- chaos

    def _consume_kills(self, now, flights, metrics):
        """Fire device-kill events due by ``now``: mark the victim's
        in-flight batch killed (it lands at the kill time, requeueing),
        drop the device from the pool.  Returns True if the pool shrank
        (the caller re-solves the placement after landings)."""
        fired, remaining = False, []
        for t, dv in self._kills:
            if t > now:
                remaining.append((t, dv))
                continue
            if len(self.alive) <= 1:
                metrics.record_event('kill_skipped', t, device=dv,
                                     reason='last device')
                continue
            if dv is None:                 # kill a busy device: prefer
                busy = sorted(             # one not already slowed
                    (f for f in flights if f.t_kill is None
                     and f.dev in self.alive
                     and f.t_dispatch <= t < f.t_end),
                    key=lambda f: (self.chaos.slow_factor(f.dev, t) > 1.0,
                                   f.dev))
                victim = busy[0].dev if busy else self.alive[0]
            else:
                if dv not in self.alive:   # already dead: consume, ignore
                    continue
                victim = dv
            inflight = next((f for f in flights
                             if f.dev == victim and f.t_kill is None
                             and f.t_dispatch <= t < f.t_end), None)
            if inflight is not None:
                inflight.t_kill = t
            metrics.record_event('kill', t, device=victim,
                                 mid_batch=inflight is not None,
                                 n_devices=len(self.alive) - 1)
            if self.tracer.enabled:
                self.tracer.instant('kill', t, track=f'device{victim}',
                                    mid_batch=inflight is not None)
            self.alive.remove(victim)
            fired = True
        self._kills = remaining
        return fired

    # ---------------------------------------------------------- event loop

    def run_trace(self, requests):
        """Event-driven serve of a whole arrival trace over the placed
        devices; returns ``({rid: Completion}, ServingMetrics)``."""
        queue = RequestQueue(requests)
        pend = [deque() for _ in range(self.n_segs)]
        completions, metrics = {}, ServingMetrics()
        self._seq, self._next_cohort, self._cohort = 0, 0, {}
        self.alive = list(range(len(self.jax_devices)))
        self.placement = self._placement0
        self._solve_and_place()
        self._free_at = {d: 0.0 for d in self.alive}
        self._kills = sorted(self.chaos.kills)
        flights = []
        now = queue.next_arrival() or 0.0
        metrics.record_event('placement', now, n_devices=len(self.alive),
                             max_load=round(self.placement.max_load, 6),
                             bound=round(self.placement.bound, 6))
        last_depth = None
        while queue or any(pend) or flights:
            fired = self._consume_kills(now, flights, metrics)
            due = sorted((f for f in flights if f.t_land <= now),
                         key=lambda f: (f.t_land, f.seq))
            for fl in due:
                flights.remove(fl)
                self._land_flight(fl, pend, queue, completions, metrics)
            if fired:                      # survivors get a fresh packing
                self.placement = None
                self._solve_and_place()
                metrics.record_event(
                    'placement', now, n_devices=len(self.alive),
                    max_load=round(self.placement.max_load, 6),
                    bound=round(self.placement.bound, 6))
            if not (queue or any(pend) or flights):
                break                      # landing drained the last work
            cap = self.slots * max(len(self.alive), 1) - len(pend[0])
            for r in queue.pop_ready(now, max(cap, 0)):
                pend[0].append((r, r.x, None, None, None))
            depth = len(pend[0]) + queue.n_ready(now)
            if depth != last_depth:
                metrics.record_gauge('queue_depth', now, depth)
                last_depth = depth
            dispatched = False
            for d in self.alive:
                if self._free_at[d] > now:
                    continue
                k = self._pick_dev(d, pend, more_arrivals=bool(queue),
                                   now=now)
                if k is None:
                    continue
                flights.append(self._dispatch(d, k, pend, metrics, now))
                dispatched = True
            if dispatched:
                continue                   # new flights may land instantly
            horizons = [f.t_land for f in flights]
            horizons += [t for t, _ in self._kills]
            nxt = queue.next_arrival()
            if nxt is not None:
                horizons.append(nxt)
            if any(pend):
                horizons += [self._free_at[d] for d in self.alive
                             if self._free_at[d] > now]
                if self.max_wait is not None:
                    oldest = min(p[0][0].t_arrival for p in pend if p)
                    horizons.append(oldest + self.max_wait)
            horizons = [h for h in horizons if h > now]
            if not horizons:
                raise RuntimeError(
                    'pipeline stalled: pending work but no future event '
                    '(this is a scheduler bug); '
                    f'now={now} pend={[len(b) for b in pend]} '
                    f'queue={len(queue)} flights={len(flights)} '
                    f'alive={self.alive}')
            now = min(horizons)
        return completions, metrics
