"""Serving model registry: named endpoints over exported artifacts.

The runtime's front door: a finished chain is persisted with
``checkpoint.save_chain_state`` (what ``Pipeline.run(checkpoint_dir=...)``
writes after every pass), and the registry turns such an artifact back
into a live :class:`~repro.core.export.ServingModel` — loading the
ChainState, exporting through the family's registered serving backend
(``calibrate`` selects the int8-resident plan the scheduler's
bit-exactness contract wants), and keeping it addressable by name so the
launcher/scheduler can route requests.

It is also the failover authority: :meth:`restore` re-exports a named
model from the SAME persisted chain checkpoint its original ``load`` used
— the replica pool (serving/replica.py) calls it when a replica dies
mid-batch, and because export is deterministic from the ChainState the
replacement replica's answers are bit-exact with the dead one's.
"""
from __future__ import annotations

from repro.checkpoint.chain_io import load_chain_state
from repro.core.export import export_chain


class ModelRegistry:
    """Name -> ServingModel map with checkpoint-backed loading."""

    def __init__(self):
        self._models = {}
        self._sources = {}        # name -> (ckpt_dir, family, load kwargs)

    def register(self, name: str, model) -> None:
        """Register an already-exported ServingModel under ``name``."""
        if name in self._models:
            raise ValueError(f'model {name!r} already registered')
        self._models[name] = model

    def load(self, name: str, ckpt_dir: str, family, *, step=None,
             use_pallas=None, calibrate=None):
        """Load a persisted ChainState and export it for serving.

        ``calibrate`` (a sample batch) compiles the int8-resident layer
        plan — required for the scheduler's bit-exact compaction; the
        chain's stored ``exit_threshold`` rides along via export_chain.
        The checkpoint source is remembered so :meth:`restore` can
        re-export the model after a replica failure.  Returns the
        registered ServingModel.
        """
        state, _ = load_chain_state(ckpt_dir, family, step=step)
        model = export_chain(state, use_pallas=use_pallas,
                             calibrate=calibrate)
        self.register(name, model)
        self._sources[name] = (ckpt_dir, family,
                               dict(step=step, use_pallas=use_pallas,
                                    calibrate=calibrate))
        return model

    def restore(self, name: str):
        """Failover: re-export ``name`` from its persisted chain
        checkpoint (the dir its ``load`` read).  Returns a FRESH
        ServingModel — bit-exact with the original because the export is
        deterministic from the ChainState — and re-points the registry
        entry at it.  Raises KeyError for models registered directly
        (no checkpoint to restore from)."""
        if name not in self._sources:
            raise KeyError(
                f'model {name!r} has no checkpoint source (registered '
                f'directly, not loaded); failover needs a load()ed model')
        ckpt_dir, family, kw = self._sources[name]
        state, _ = load_chain_state(ckpt_dir, family, step=kw['step'])
        model = export_chain(state, use_pallas=kw['use_pallas'],
                             calibrate=kw['calibrate'])
        self._models[name] = model
        return model

    # ------------------------------------------------ multi-model placement

    def plan_placement(self, n_devices: int, stage_costs: dict, *,
                       seed: int = 0):
        """Pack every registered model's stages onto ``n_devices``.

        ``stage_costs`` maps model name -> measured per-stage batch costs
        (each model at its own slot geometry — the cost IS the geometry's
        price), covering every registered name.  Returns the greedy-LPT
        :class:`~repro.serving.placement.Placement` over all N chains:
        ``placement.device_of(stage, model=name)`` answers per model, and
        ``placement.summary()`` reports the achieved per-device loads
        against the LPT load-balance bound.
        """
        from repro.serving.placement import solve_placement
        if not self._models:
            raise ValueError('no models registered to place')
        missing = [n for n in self.names() if n not in stage_costs]
        if missing:
            raise ValueError(f'stage_costs missing for registered '
                             f'model(s) {missing}')
        for name in self.names():
            n_stages = self._models[name].n_stages
            if len(stage_costs[name]) != n_stages:
                raise ValueError(
                    f'model {name!r}: {len(stage_costs[name])} stage '
                    f'costs for {n_stages} stages')
        return solve_placement(
            {name: stage_costs[name] for name in self.names()},
            n_devices, seed=seed)

    def place(self, name: str, placement, devices):
        """Apply a solved placement to a registered model: re-points the
        entry at ``model.place_stages(...)`` with stage *k* pinned to
        ``devices[placement.device_of(k, model=name)]``, and returns the
        placed model.  ``devices`` is the ordinal->jax-device list the
        placement was solved over."""
        model = self.get(name)
        placed = model.place_stages(tuple(
            devices[placement.device_of(k, model=name)]
            for k in range(model.n_stages)))
        self._models[name] = placed
        return placed

    def get(self, name: str):
        if name not in self._models:
            raise KeyError(f'no serving model {name!r} '
                           f'(registered: {sorted(self._models)})')
        return self._models[name]

    def names(self) -> list:
        return sorted(self._models)

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def __len__(self) -> int:
        return len(self._models)
