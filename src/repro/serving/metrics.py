"""Serving metrics: latency percentiles, throughput, exit mix, occupancy.

One :class:`ServingMetrics` instance rides along with a scheduler run.  The
scheduler reports every completion and every executed batch (stage index +
live-slot count); ``summary()`` folds them into the numbers the benchmark
records — p50/p99 latency, throughput over the makespan, the per-stage
exit distribution, and batch occupancy (the fraction of slots doing useful
work, the quantity early-exit compaction exists to raise).

Percentiles interpolate between order statistics (numpy's 'linear'
definition) so small smoke traces still give stable numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def percentile(values, q: float) -> float:
    """Linear-interpolated percentile of ``values`` (q in [0, 100])."""
    xs = sorted(float(v) for v in values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclass
class ServingMetrics:
    """Accumulates per-completion and per-batch records for one run."""
    latencies: list = field(default_factory=list)
    exit_stages: list = field(default_factory=list)
    batches: list = field(default_factory=list)   # (stage_idx, live, slots)
    t_first_arrival: float | None = None
    t_last_done: float = 0.0

    def record_completion(self, c) -> None:
        self.latencies.append(c.latency)
        self.exit_stages.append(c.exit_stage)
        if self.t_first_arrival is None or c.t_arrival < self.t_first_arrival:
            self.t_first_arrival = c.t_arrival
        self.t_last_done = max(self.t_last_done, c.t_done)

    def record_batch(self, stage_idx: int, live: int, slots: int) -> None:
        self.batches.append((stage_idx, live, slots))

    def summary(self) -> dict:
        n = len(self.latencies)
        makespan = (self.t_last_done - (self.t_first_arrival or 0.0)
                    if n else 0.0)
        exited = sum(1 for s in self.exit_stages if s >= 0)
        stages = sorted({s for s, _, _ in self.batches})
        occ = {s: [l for st, l, _ in self.batches if st == s]
               for s in stages}
        slots = {s: next(sl for st, _, sl in self.batches if st == s)
                 for s in stages}
        return {
            'n_requests': n,
            'p50_latency_s': round(percentile(self.latencies, 50), 6),
            'p99_latency_s': round(percentile(self.latencies, 99), 6),
            'throughput_rps': round(n / makespan, 3) if makespan > 0 else 0.0,
            'exit_fraction': round(exited / n, 4) if n else 0.0,
            'exit_mix': {str(s): self.exit_stages.count(s)
                         for s in sorted(set(self.exit_stages))},
            'n_batches': {str(s): len(occ[s]) for s in stages},
            'batch_occupancy': {
                str(s): round(sum(occ[s]) / (len(occ[s]) * slots[s]), 4)
                for s in stages if occ[s]},
        }
