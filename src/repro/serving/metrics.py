"""Serving metrics: latency percentiles, throughput, exit mix, occupancy,
SLO attainment, and resilience events.

One :class:`ServingMetrics` instance rides along with a scheduler run.  The
scheduler reports every completion, every executed batch (stage index +
live-slot count), every SLO rejection, and — on the replica pool — every
resilience event (replica kill, failover, straggler flag, scale up/down);
``summary()`` folds them into the numbers the benchmarks record:

* p50/p99 end-to-end latency, split into **queue-wait** (arrival ->
  service start, ``Completion.t_start``) and **execute** (service start ->
  done) percentiles;
* throughput over the makespan and batch occupancy (the fraction of slots
  doing useful work, the quantity early-exit compaction exists to raise);
* **availability** (completions / offered requests — 1.0 means zero lost
  even under chaos), **SLO attainment** (on-time completions over all
  deadline-carrying requests, rejected included), the **degraded-exit
  mix** (requests the SLO layer force-exited at an earlier head), and
  ``n_late`` — by the never-late contract this must be 0;
* resilience counters: ``failovers``, ``kills``, ``straggler_flags``,
  ``scale_ups``/``scale_downs``, peak replica count.

Percentiles interpolate between order statistics (numpy's 'linear'
definition) so small smoke traces still give stable numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def percentile(values, q: float) -> float:
    """Linear-interpolated percentile of ``values`` (q in [0, 100])."""
    xs = sorted(float(v) for v in values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclass
class ServingMetrics:
    """Accumulates per-completion and per-batch records for one run."""
    latencies: list = field(default_factory=list)
    queue_waits: list = field(default_factory=list)
    executes: list = field(default_factory=list)
    exit_stages: list = field(default_factory=list)
    degraded_stages: list = field(default_factory=list)
    batches: list = field(default_factory=list)   # (stage_idx, live, slots)
    rejections: list = field(default_factory=list)  # (rid, t, reason)
    events: list = field(default_factory=list)    # (kind, t, info)
    n_deadline: int = 0
    n_on_time: int = 0
    n_late: int = 0
    t_first_arrival: float | None = None
    t_last_done: float = 0.0

    def record_completion(self, c) -> None:
        self.latencies.append(c.latency)
        self.exit_stages.append(c.exit_stage)
        if c.degraded:
            self.degraded_stages.append(c.exit_stage)
        if c.t_start is not None:
            self.queue_waits.append(c.queue_wait)
            self.executes.append(c.execute)
        if c.deadline is not None:
            self.n_deadline += 1
            if c.on_time:
                self.n_on_time += 1
            else:
                self.n_late += 1
        if self.t_first_arrival is None or c.t_arrival < self.t_first_arrival:
            self.t_first_arrival = c.t_arrival
        self.t_last_done = max(self.t_last_done, c.t_done)

    def record_batch(self, stage_idx: int, live: int, slots: int) -> None:
        self.batches.append((stage_idx, live, slots))

    def record_rejection(self, rid: int, t: float, reason: str) -> None:
        """An SLO-rejected request: counted, never served late."""
        self.rejections.append((rid, t, reason))

    def record_event(self, kind: str, t: float, **info) -> None:
        """A resilience event from the replica pool: 'kill', 'failover',
        'straggler_flag', 'scale_up', 'scale_down', 'evict'."""
        self.events.append((kind, t, info))

    def _count_events(self, kind: str) -> int:
        return sum(1 for k, _, _ in self.events if k == kind)

    def summary(self) -> dict:
        n = len(self.latencies)
        offered = n + len(self.rejections)
        makespan = (self.t_last_done - (self.t_first_arrival or 0.0)
                    if n else 0.0)
        exited = sum(1 for s in self.exit_stages if s >= 0)
        stages = sorted({s for s, _, _ in self.batches})
        occ = {s: [l for st, l, _ in self.batches if st == s]
               for s in stages}
        slots = {s: next(sl for st, _, sl in self.batches if st == s)
                 for s in stages}
        out = {
            'n_requests': n,
            'p50_latency_s': round(percentile(self.latencies, 50), 6),
            'p99_latency_s': round(percentile(self.latencies, 99), 6),
            'p50_queue_wait_s': round(percentile(self.queue_waits, 50), 6),
            'p99_queue_wait_s': round(percentile(self.queue_waits, 99), 6),
            'p50_execute_s': round(percentile(self.executes, 50), 6),
            'p99_execute_s': round(percentile(self.executes, 99), 6),
            'throughput_rps': round(n / makespan, 3) if makespan > 0 else 0.0,
            'exit_fraction': round(exited / n, 4) if n else 0.0,
            'exit_mix': {str(s): self.exit_stages.count(s)
                         for s in sorted(set(self.exit_stages))},
            'n_batches': {str(s): len(occ[s]) for s in stages},
            'batch_occupancy': {
                str(s): round(sum(occ[s]) / (len(occ[s]) * slots[s]), 4)
                for s in stages if occ[s]},
            'availability': round(n / offered, 4) if offered else 0.0,
            'n_rejected': len(self.rejections),
            'n_degraded': len(self.degraded_stages),
            'degraded_exit_mix': {
                str(s): self.degraded_stages.count(s)
                for s in sorted(set(self.degraded_stages))},
        }
        if self.n_deadline or self.rejections:
            denom = self.n_deadline + len(self.rejections)
            out['slo'] = {
                'n_with_deadline': denom,
                'n_on_time': self.n_on_time,
                'n_late': self.n_late,
                'attainment': round(self.n_on_time / denom, 4)
                if denom else 0.0,
            }
        if self.events:
            out['resilience'] = {
                'kills': self._count_events('kill'),
                'failovers': self._count_events('failover'),
                'straggler_flags': self._count_events('straggler_flag'),
                'evictions': self._count_events('evict'),
                'scale_ups': self._count_events('scale_up'),
                'scale_downs': self._count_events('scale_down'),
                'peak_replicas': max(
                    (i.get('n_replicas', 0) for _, _, i in self.events),
                    default=0),
            }
        return out
