"""Serving metrics: latency percentiles, throughput, exit mix, occupancy,
SLO attainment, and resilience events.

One :class:`ServingMetrics` instance rides along with a scheduler run.  The
scheduler reports every completion, every executed batch (stage index +
live-slot count), every SLO rejection, and — on the replica pool — every
resilience event (replica kill, failover, straggler flag, scale up/down);
``summary()`` folds them into the numbers the benchmarks record:

* p50/p99 end-to-end latency, split into **queue-wait** (arrival ->
  service start, ``Completion.t_start``) and **execute** (service start ->
  done) percentiles;
* throughput over the makespan and batch occupancy (the fraction of slots
  doing useful work, the quantity early-exit compaction exists to raise);
* **availability** (completions / offered requests — 1.0 means zero lost
  even under chaos), **SLO attainment** (on-time completions over all
  deadline-carrying requests, rejected included), the **degraded-exit
  mix** (requests the SLO layer force-exited at an earlier head), and
  ``n_late`` — by the never-late contract this must be 0;
* resilience counters: ``failovers``, ``kills``, ``straggler_flags``,
  ``scale_ups``/``scale_downs``, peak replica count.

Beyond the aggregates, the instance keeps *timestamped* samples —
``(t_done, latency)`` per completion, ``(t, stage, live, slots, cost)``
per batch, and named gauges (``queue_depth``, ``replicas``) — and
``timeseries()`` folds them into fixed-window series (queue depth
mean/peak, rolling p99 latency, occupancy, replica count, per-stage exec
share) recorded into the BENCH JSONs; ``telemetry_digest()`` compresses
that into the one-liner the benchmarks print.

Makespan starts at the earliest *offered* arrival (completions AND
SLO rejections — ``record_rejection`` takes the request's ``t_arrival``),
so a run whose earliest arrivals are all rejected does not report an
inflated throughput.

Percentiles interpolate between order statistics (numpy's 'linear'
definition) so small smoke traces still give stable numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def percentile(values, q: float) -> float:
    """Linear-interpolated percentile of ``values`` (q in [0, 100])."""
    xs = sorted(float(v) for v in values)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclass
class ServingMetrics:
    """Accumulates per-completion and per-batch records for one run."""
    latencies: list = field(default_factory=list)
    queue_waits: list = field(default_factory=list)
    executes: list = field(default_factory=list)
    exit_stages: list = field(default_factory=list)
    degraded_stages: list = field(default_factory=list)
    batches: list = field(default_factory=list)   # (stage_idx, live, slots)
    rejections: list = field(default_factory=list)  # (rid, t, reason)
    events: list = field(default_factory=list)    # (kind, t, info)
    lat_samples: list = field(default_factory=list)   # (t_done, latency)
    batch_samples: list = field(default_factory=list)
    # ^ (t, stage_idx, live, slots, cost) — only when the scheduler passes t
    gauges: dict = field(default_factory=dict)    # name -> [(t, value)]
    device_samples: list = field(default_factory=list)  # (t, cost, device)
    n_deadline: int = 0
    n_on_time: int = 0
    n_late: int = 0
    t_first_arrival: float | None = None
    t_first_offered: float | None = None          # completions + rejections
    t_last_done: float = 0.0

    def _offer(self, t_arrival: float) -> None:
        if self.t_first_offered is None or t_arrival < self.t_first_offered:
            self.t_first_offered = t_arrival

    def record_completion(self, c) -> None:
        self.latencies.append(c.latency)
        self.lat_samples.append((c.t_done, c.latency))
        self.exit_stages.append(c.exit_stage)
        if c.degraded:
            self.degraded_stages.append(c.exit_stage)
        if c.t_start is not None:
            self.queue_waits.append(c.queue_wait)
            self.executes.append(c.execute)
        if c.deadline is not None:
            self.n_deadline += 1
            if c.on_time:
                self.n_on_time += 1
            else:
                self.n_late += 1
        if self.t_first_arrival is None or c.t_arrival < self.t_first_arrival:
            self.t_first_arrival = c.t_arrival
        self._offer(c.t_arrival)
        self.t_last_done = max(self.t_last_done, c.t_done)

    def record_batch(self, stage_idx: int, live: int, slots: int,
                     t: float | None = None,
                     cost: float | None = None,
                     device: int | None = None) -> None:
        """``device`` (the pipeline scheduler passes its device ordinal)
        additionally feeds the per-device busy series behind
        :meth:`device_occupancy`."""
        self.batches.append((stage_idx, live, slots))
        if t is not None:
            self.batch_samples.append((t, stage_idx, live, slots,
                                       0.0 if cost is None else cost))
            if device is not None:
                self.device_samples.append((t, 0.0 if cost is None
                                            else cost, device))

    def record_rejection(self, rid: int, t: float, reason: str,
                         t_arrival: float | None = None) -> None:
        """An SLO-rejected request: counted, never served late.  Pass the
        request's ``t_arrival`` so the makespan covers offered load even
        when the earliest arrivals were all rejected."""
        self.rejections.append((rid, t, reason))
        self._offer(t if t_arrival is None else t_arrival)

    def record_gauge(self, name: str, t: float, value: float) -> None:
        """A sampled time-series value ('queue_depth', 'replicas', ...)."""
        self.gauges.setdefault(name, []).append((t, float(value)))

    def record_event(self, kind: str, t: float, **info) -> None:
        """A resilience event from the replica pool: 'kill', 'failover',
        'straggler_flag', 'scale_up', 'scale_down', 'evict'.  Events that
        carry ``n_replicas`` also sample the 'replicas' gauge, so replica
        count over time falls out of the existing event stream."""
        self.events.append((kind, t, info))
        if 'n_replicas' in info:
            self.record_gauge('replicas', t, info['n_replicas'])

    def _count_events(self, kind: str) -> int:
        return sum(1 for k, _, _ in self.events if k == kind)

    def summary(self) -> dict:
        n = len(self.latencies)
        offered = n + len(self.rejections)
        first = (self.t_first_offered if self.t_first_offered is not None
                 else self.t_first_arrival)
        makespan = self.t_last_done - (first or 0.0) if n else 0.0
        exited = sum(1 for s in self.exit_stages if s >= 0)
        stages = sorted({s for s, _, _ in self.batches})
        occ = {s: [l for st, l, _ in self.batches if st == s]
               for s in stages}
        slots = {s: next(sl for st, _, sl in self.batches if st == s)
                 for s in stages}
        out = {
            'n_requests': n,
            'p50_latency_s': round(percentile(self.latencies, 50), 6),
            'p99_latency_s': round(percentile(self.latencies, 99), 6),
            'p50_queue_wait_s': round(percentile(self.queue_waits, 50), 6),
            'p99_queue_wait_s': round(percentile(self.queue_waits, 99), 6),
            'p50_execute_s': round(percentile(self.executes, 50), 6),
            'p99_execute_s': round(percentile(self.executes, 99), 6),
            'throughput_rps': round(n / makespan, 3) if makespan > 0 else 0.0,
            'exit_fraction': round(exited / n, 4) if n else 0.0,
            'exit_mix': {str(s): self.exit_stages.count(s)
                         for s in sorted(set(self.exit_stages))},
            'n_batches': {str(s): len(occ[s]) for s in stages},
            'batch_occupancy': {
                str(s): round(sum(occ[s]) / (len(occ[s]) * slots[s]), 4)
                for s in stages if occ[s]},
            'availability': round(n / offered, 4) if offered else 0.0,
            'n_rejected': len(self.rejections),
            'n_degraded': len(self.degraded_stages),
            'degraded_exit_mix': {
                str(s): self.degraded_stages.count(s)
                for s in sorted(set(self.degraded_stages))},
        }
        if self.n_deadline or self.rejections:
            denom = self.n_deadline + len(self.rejections)
            out['slo'] = {
                'n_with_deadline': denom,
                'n_on_time': self.n_on_time,
                'n_late': self.n_late,
                'attainment': round(self.n_on_time / denom, 4)
                if denom else 0.0,
            }
        if self.events:
            out['resilience'] = {
                'kills': self._count_events('kill'),
                'failovers': self._count_events('failover'),
                'straggler_flags': self._count_events('straggler_flag'),
                'evictions': self._count_events('evict'),
                'scale_ups': self._count_events('scale_up'),
                'scale_downs': self._count_events('scale_down'),
                'peak_replicas': max(
                    (i.get('n_replicas', 0) for _, _, i in self.events),
                    default=0),
            }
        return out

    # ------------------------------------------------------- time series

    def timeseries(self, n_windows: int = 24) -> dict:
        """Fold the timestamped samples into ``n_windows`` equal windows
        over the run (earliest offered arrival -> last completion).
        Empty latency/occupancy windows report ``None`` (no samples, not
        zero); gauge windows carry the last known value forward."""
        t0 = (self.t_first_offered if self.t_first_offered is not None
              else (self.t_first_arrival or 0.0))
        t1 = self.t_last_done
        if t1 <= t0 or not (self.lat_samples or self.batch_samples):
            return {}
        w = (t1 - t0) / n_windows

        def bucket(t):
            return min(n_windows - 1, max(0, int((t - t0) / w)))

        lat_bins = [[] for _ in range(n_windows)]
        for t, lat in self.lat_samples:
            lat_bins[bucket(t)].append(lat)
        rolling_p99 = [round(percentile(b, 99), 6) if b else None
                       for b in lat_bins]
        occ_bins = [[] for _ in range(n_windows)]
        stage_cost: dict[int, float] = {}
        for t, stage, live, slots, cost in self.batch_samples:
            occ_bins[bucket(t)].append(live / slots)
            stage_cost[stage] = stage_cost.get(stage, 0.0) + cost
        occupancy = [round(sum(b) / len(b), 4) if b else None
                     for b in occ_bins]
        total_cost = sum(stage_cost.values())
        exec_share = {str(s): round(c / total_cost, 4)
                      for s, c in sorted(stage_cost.items())} \
            if total_cost > 0 else {}
        out = {
            'n_windows': n_windows,
            'window_s': round(w, 6),
            't0': round(t0, 6),
            'completions': [len(b) for b in lat_bins],
            'rolling_p99_s': rolling_p99,
            'occupancy': occupancy,
            'stage_exec_share': exec_share,
        }
        for name, samples in sorted(self.gauges.items()):
            mean_bins = [[] for _ in range(n_windows)]
            peak = [None] * n_windows
            for t, v in samples:
                b = bucket(t)
                mean_bins[b].append(v)
                peak[b] = v if peak[b] is None else max(peak[b], v)
            last = None                    # carry forward through gaps
            for i in range(n_windows):
                if mean_bins[i]:
                    last = mean_bins[i][-1]
                elif last is not None:
                    peak[i] = last
            out[name] = {
                'mean': [round(sum(b) / len(b), 3) if b
                         else peak[i] for i, b in enumerate(mean_bins)],
                'peak': peak,
                'overall_peak': max((v for _, v in samples), default=0.0),
            }
        worst = [(p, i) for i, p in enumerate(rolling_p99) if p is not None]
        if worst:
            p, i = max(worst)
            out['worst_p99_window'] = {
                'p99_s': p,
                't_start': round(t0 + i * w, 6),
                't_end': round(t0 + (i + 1) * w, 6),
            }
        return out

    def device_occupancy(self, n_windows: int = 24) -> dict:
        """Per-device busy-fraction time series over the run window.

        Each executed batch the scheduler tagged with a ``device``
        contributes its ``[t, t + cost)`` interval to that device's busy
        time; every window reports ``busy / window`` per device (a device
        saturating a window reads 1.0).  Empty unless the scheduler
        records device ordinals (the pipeline scheduler does)."""
        if not self.device_samples:
            return {}
        t0 = (self.t_first_offered if self.t_first_offered is not None
              else (self.t_first_arrival or 0.0))
        t1 = max(self.t_last_done,
                 max(t + c for t, c, _ in self.device_samples))
        if t1 <= t0:
            return {}
        w = (t1 - t0) / n_windows
        devices = sorted({d for _, _, d in self.device_samples})
        busy = {d: [0.0] * n_windows for d in devices}
        for t, cost, d in self.device_samples:
            a, b = t, t + cost
            i0 = max(0, int((a - t0) / w))
            i1 = min(n_windows - 1, int((b - t0) / w))
            for i in range(i0, i1 + 1):
                lo, hi = t0 + i * w, t0 + (i + 1) * w
                overlap = min(b, hi) - max(a, lo)
                if overlap > 0:
                    busy[d][i] += overlap
        return {str(d): [round(v / w, 4) for v in busy[d]]
                for d in devices}

    def telemetry_digest(self, n_windows: int = 24) -> str:
        """One line for benchmark logs: peak queue depth, worst rolling-p99
        window, per-stage exec share."""
        ts = self.timeseries(n_windows)
        if not ts:
            return 'telemetry: no timestamped samples'
        parts = []
        depth = ts.get('queue_depth')
        if depth:
            parts.append(f"peak queue depth {depth['overall_peak']:.0f}")
        worst = ts.get('worst_p99_window')
        if worst:
            parts.append(
                f"worst p99 {worst['p99_s'] * 1e3:.2f}ms in "
                f"[{worst['t_start']:.3f}s, {worst['t_end']:.3f}s)")
        if ts['stage_exec_share']:
            share = ' '.join(f's{k}={v:.0%}'
                             for k, v in ts['stage_exec_share'].items())
            parts.append(f'exec share {share}')
        reps = ts.get('replicas')
        if reps:
            parts.append(f"peak replicas {reps['overall_peak']:.0f}")
        return 'telemetry: ' + ' | '.join(parts)
