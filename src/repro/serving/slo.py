"""SLO policy: deadline-aware admission and graceful degradation.

A :class:`~repro.serving.request.Request` may carry an absolute
``deadline`` (same clock as its ``t_arrival``).  The scheduler threads an
:class:`SLOPolicy` through three decision points, all made BEFORE the
clock advances so a decision can never itself be late:

* **admission** — a request is rejected at pop time when its remaining
  budget cannot cover the segment-0 batches already queued ahead of it
  plus one head-of-line blocking execution (``admit``).  A rejected
  request is counted (``ServingMetrics.record_rejection``), never served
  late.
* **urgency override** — the wait-to-fill policy is overridden when any
  pending request's latest safe start (``deadline - cost(segment)``)
  would pass while the scheduler waits or runs another batch; the urgent
  segment runs as a partial batch instead (``urgent_segment``).
* **graceful degradation** — survivors of segment ``k`` hold their exit
  head's logits (the scheduler keeps the head row alongside the carry).
  Before an execution of cost ``c`` is charged, any pending request whose
  budget no longer covers ``c`` plus its own segment is force-completed
  NOW with those stored logits — a *degraded* completion at exit head
  ``k``, on time by construction (the check runs at ``now``, which is
  still within budget).  The E pass's exit heads thereby become a
  latency/accuracy dial: a late-budget request answers from the deepest
  head it could afford instead of blowing p99.

Per-segment batch costs come from the scheduler's simulated-clock
``stage_costs`` or are learned online (EWMA over observed wall-clock
batch costs) — on the simulated clock the estimates are exact and the
never-late guarantee is provable; on the wall clock it is best-effort
(the EWMA lags genuine cost shifts by a few batches).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class SLOPolicy:
    """Deadline admission + degradation decisions over per-segment costs.

    ``stage_costs`` is the current per-segment batch-cost estimate in
    clock seconds (simulated or wall).  ``None`` entries mean "not yet
    observed" and are treated as 0 — the policy admits everything until
    it has measurements, then tightens.  ``slack`` multiplies every cost
    estimate (>1 = conservative admission/degradation headroom).
    """
    stage_costs: list | None = None
    alpha: float = 0.25               # EWMA blend for observed batch costs
    slack: float = 1.0                # cost-estimate safety multiplier
    n_rejected: int = field(default=0, init=False)
    n_degraded: int = field(default=0, init=False)

    def _cost(self, k: int) -> float:
        if not self.stage_costs or self.stage_costs[k] is None:
            return 0.0
        return float(self.stage_costs[k]) * self.slack

    @property
    def max_cost(self) -> float:
        if not self.stage_costs:
            return 0.0
        return max(self._cost(k) for k in range(len(self.stage_costs)))

    def seed(self, stage_costs) -> None:
        """Install initial per-segment cost estimates (the scheduler's
        simulated ``stage_costs``, or a measured median)."""
        self.stage_costs = [float(c) for c in stage_costs]

    def observe(self, k: int, cost: float) -> None:
        """Fold an observed segment-``k`` batch cost into the estimate
        (EWMA; the wall-clock path's online calibration).  On the
        simulated clock the observation equals the estimate — a no-op."""
        if self.stage_costs is None:
            return
        old = self.stage_costs[k]
        self.stage_costs[k] = (cost if old is None
                               else (1 - self.alpha) * old + self.alpha * cost)

    # ------------------------------------------------------------ decisions

    def admit(self, deadline: float, now: float, backlog: int,
              slots: int) -> bool:
        """Can a request joining ``backlog`` queued segment-0 requests
        still reach the first exit head by ``deadline``?  Budgets the
        segment-0 batches ahead of it plus one head-of-line blocking
        execution of any other segment."""
        return self.admit_explain(deadline, now, backlog, slots)[0]

    def admit_explain(self, deadline: float, now: float, backlog: int,
                      slots: int) -> tuple[bool, float, float]:
        """:meth:`admit` plus its evidence: ``(admitted, budget, need)``
        — what the request had vs what the queue ahead of it costs.  The
        observability layer records these on rejection instants so a
        trace explains WHY a request was turned away."""
        batches = math.ceil((backlog + 1) / max(slots, 1))
        need = batches * self._cost(0) + self.max_cost
        budget = deadline - now
        return budget >= need, budget, need

    def latest_start(self, k: int, deadline: float) -> float:
        """Latest time segment ``k`` may start and still answer by
        ``deadline`` (at its end head, or the final head for the last
        segment)."""
        return deadline - self._cost(k)

    def urgent_segment(self, pend, now: float) -> int | None:
        """The segment that must run NOW (partial batch allowed) because
        some pending deadline's latest safe start falls within one
        worst-case blocking execution of ``now``; None when no deadline
        is at risk.  Ties break toward the tightest latest start."""
        best = None
        for j, buf in enumerate(pend):
            for item in buf:
                d = item[0].deadline
                if d is None:
                    continue
                ls = self.latest_start(j, d)
                if ls <= now + self.max_cost and \
                        (best is None or ls < best[0]):
                    best = (ls, j)
        return None if best is None else best[1]

    def wake(self, pend, now: float) -> float | None:
        """Earliest time any pending deadline becomes urgent — the
        scheduler must not sleep past it (None when no deadlines pend)."""
        ls = [self.latest_start(j, item[0].deadline)
              for j, buf in enumerate(pend) for item in buf
              if item[0].deadline is not None]
        if not ls:
            return None
        return max(now, min(ls) - self.max_cost)

    def affordable(self, deadline: float, now: float, k: int,
                   charge: float, in_batch: bool) -> bool:
        """Will a pending segment-``k`` request still meet ``deadline``
        after an execution of cost ``charge``?  ``in_batch`` means the
        request is IN that execution (it answers at ``now + charge``);
        otherwise it must additionally fit its own segment afterwards."""
        need = charge if in_batch else charge + self._cost(k)
        return deadline >= now + need - 1e-12
