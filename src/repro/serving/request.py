"""Requests, completions, and the arrival queue for the serving runtime.

A :class:`Request` is one sample (one image) with an arrival timestamp; a
:class:`Completion` is the scheduler's answer — the request's logits (the
exit head's when it exited early, the final head's otherwise), the argmax
prediction, which stage it exited at, and the latency split.  Timestamps
are plain float seconds on whatever clock drives the scheduler (wall clock
or the benchmark's simulated cost-model clock).

:class:`RequestQueue` is the arrival buffer: FIFO, time-aware — the
scheduler only admits requests whose arrival time has passed on its clock,
so a recorded Poisson trace replays faithfully.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any


@dataclass
class Request:
    """One inference request: ``x`` is a single unbatched sample (H, W, C)."""
    rid: int
    x: Any
    t_arrival: float = 0.0


@dataclass
class Completion:
    """The served answer for one request."""
    rid: int
    logits: Any                # the head that answered (exit or final), fp32
    pred: int
    exit_stage: int            # stage index of the exit taken; -1 = final head
    t_arrival: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


class RequestQueue:
    """FIFO arrival queue with time-gated admission."""

    def __init__(self, requests=()):
        self._q = deque(sorted(requests, key=lambda r: r.t_arrival))

    def push(self, req: Request) -> None:
        if self._q and req.t_arrival < self._q[-1].t_arrival:
            raise ValueError(
                f'request {req.rid} arrives at {req.t_arrival} before the '
                f'queue tail ({self._q[-1].t_arrival}); push in arrival order')
        self._q.append(req)

    def pop_ready(self, now: float, limit: int) -> list:
        """Up to ``limit`` requests that have arrived by ``now``, FIFO."""
        out = []
        while self._q and len(out) < limit and self._q[0].t_arrival <= now:
            out.append(self._q.popleft())
        return out

    def next_arrival(self) -> float | None:
        """Arrival time of the head request (None when empty)."""
        return self._q[0].t_arrival if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
