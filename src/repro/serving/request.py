"""Requests, completions, and the arrival queue for the serving runtime.

A :class:`Request` is one sample (one image) with an arrival timestamp and
an optional absolute ``deadline`` (the SLO layer, serving/slo.py); a
:class:`Completion` is the scheduler's answer — the request's logits (the
exit head's when it exited early, the final head's otherwise), the argmax
prediction, which stage it exited at, and the latency split.  Timestamps
are plain float seconds on whatever clock drives the scheduler (wall clock
or the benchmark's simulated cost-model clock).

:class:`RequestQueue` is the arrival buffer: FIFO, time-aware — the
scheduler only admits requests whose arrival time has passed on its clock,
so a recorded Poisson trace replays faithfully.  ``push`` validates that a
*fresh* trace arrives in order; ``requeue`` is the failover-replay path —
a request whose replica died mid-batch re-enters at its FIFO position by
original arrival time, which an in-order ``push`` would forbid.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any


@dataclass
class Request:
    """One inference request: ``x`` is a single unbatched sample (H, W, C).

    ``deadline`` is absolute (same clock as ``t_arrival``); None = no SLO.
    ``t_start`` is written by the scheduler when the request first enters
    an executed segment-0 batch (service start; queue-wait ends here).
    ``t_enqueued`` is the last time the request (re-)entered the queue —
    ``None`` until a failover requeue stamps the kill time, so a traced
    request's second ``request.queue`` span starts where its killed
    dispatch ended instead of double-counting the original wait.
    """
    rid: int
    x: Any
    t_arrival: float = 0.0
    deadline: float | None = None
    t_start: float | None = None
    t_enqueued: float | None = None


@dataclass
class Completion:
    """The served answer for one request."""
    rid: int
    logits: Any                # the head that answered (exit or final), fp32
    pred: int
    exit_stage: int            # stage index of the exit taken; -1 = final head
    t_arrival: float
    t_done: float
    t_start: float | None = None   # first segment-0 execution start
    deadline: float | None = None  # absolute SLO deadline (None = no SLO)
    degraded: bool = False         # forced to an earlier head by the SLO

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def queue_wait(self) -> float | None:
        """Arrival -> service start (None if never dispatched)."""
        return None if self.t_start is None else self.t_start - self.t_arrival

    @property
    def execute(self) -> float | None:
        """Service start -> completion (includes inter-segment waits)."""
        return None if self.t_start is None else self.t_done - self.t_start

    @property
    def on_time(self) -> bool | None:
        """Deadline met?  None when the request carried no deadline."""
        if self.deadline is None:
            return None
        return self.t_done <= self.deadline + 1e-12


class RequestQueue:
    """FIFO arrival queue with time-gated admission."""

    def __init__(self, requests=()):
        self._q = deque(sorted(requests, key=lambda r: r.t_arrival))

    def push(self, req: Request) -> None:
        """Append a FRESH request; raises unless pushed in arrival order
        (trace validation — an out-of-order fresh push is a bug, while a
        failover replay must go through :meth:`requeue`)."""
        if self._q and req.t_arrival < self._q[-1].t_arrival:
            raise ValueError(
                f'request {req.rid} arrives at {req.t_arrival} before the '
                f'queue tail ({self._q[-1].t_arrival}); push in arrival '
                f'order (failover replay goes through requeue())')
        self._q.append(req)

    def requeue(self, req: Request) -> None:
        """Re-admit a request (failover replay: its executor died before
        its batch landed).  Inserts at the FIFO position of its ORIGINAL
        arrival time, so replayed requests keep their place relative to
        requests still waiting — the order a fresh in-order trace would
        have produced."""
        for i, r in enumerate(self._q):
            if r.t_arrival > req.t_arrival:
                self._q.insert(i, req)
                return
        self._q.append(req)

    def pop_ready(self, now: float, limit: int) -> list:
        """Up to ``limit`` requests that have arrived by ``now``, FIFO."""
        out = []
        while self._q and len(out) < limit and self._q[0].t_arrival <= now:
            out.append(self._q.popleft())
        return out

    def next_arrival(self) -> float | None:
        """Arrival time of the head request (None when empty)."""
        return self._q[0].t_arrival if self._q else None

    def n_ready(self, now: float) -> int:
        """How many queued requests have arrived by ``now`` (the replica
        pool's scaling signal; FIFO order means they are a prefix)."""
        n = 0
        for r in self._q:
            if r.t_arrival > now:
                break
            n += 1
        return n

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
