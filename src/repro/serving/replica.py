"""Replica-pool scheduler: elastic executors, straggler de-prioritization,
chaos-tested checkpoint-backed failover.

This folds the seed ``runtime/`` ideas into the serving path as one
event-driven scheduler over N *logical replicas* of an exported model:

* **elastic.py's idea** — the pool scales replica count from observed load
  (queued + pending requests over the slot geometry), spinning replicas up
  with a configurable delay and retiring idle ones;
* **straggler.py's monitor** — re-keyed from hosts to replicas: every
  landed batch feeds ``cost / expected_stage_cost`` into the shared
  :class:`~repro.runtime.straggler.StragglerMonitor` EWMA
  (``observe_one``); flagged replicas are de-prioritized for new
  dispatches and, after ``evict_after`` consecutive flags, replaced;
* **ft.py's pattern** — a :class:`ChaosPlan` injects
  :class:`~repro.runtime.SimulatedFailure` kills (a replica dies mid-batch
  or idle) and straggler slowdowns at seeded times.  A killed replica's
  in-flight requests *requeue* — segment-0 requests through
  ``RequestQueue.requeue`` (FIFO by original arrival), deeper ones at the
  front of their pending buffer with their carry intact — and a
  replacement is restored through the caller's ``restore`` hook, normally
  :meth:`~repro.serving.registry.ModelRegistry.restore`, which re-exports
  the model from its persisted chain checkpoint
  (``checkpoint/chain_io.py``).

Bit-exactness under chaos: every completion is computed by a
deterministically-compiled segment on the fixed slot geometry, and a
requeued request re-runs its segment on the SAME carry rows — so answers
are bit-exact vs an undisturbed run (and vs the request-alone monolithic
oracle) no matter how many kills, slowdowns, or requeues happened on the
way.  The resident-export slot-independence contract makes this provable;
``benchmarks/serving_load.py --chaos`` asserts it on every run.

The pool runs on the **simulated clock only** (``stage_costs`` required):
one host process cannot execute replicas concurrently for real, but it
can execute their batches eagerly and order completions by simulated
event time — which also makes chaos runs deterministic and the SLO
never-late guarantee exact (a flight's cost, including its replica's
slowdown, is known at dispatch).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass

import jax
import numpy as np

from repro.runtime.ft import SimulatedFailure
from repro.runtime.straggler import StragglerMonitor
from repro.serving.metrics import ServingMetrics
from repro.serving.request import RequestQueue
from repro.serving.scheduler import ContinuousBatchScheduler, _gather_rows


@dataclass(frozen=True)
class ChaosPlan:
    """Seeded failure schedule: ``kills`` are ``(t, replica_id)`` — the
    replica dies at ``t`` (mid-batch if one is in flight); ``replica_id
    None`` kills whichever replica is busy at ``t`` (a real chaos
    harness's "kill a node doing work", preferring one that is not
    already a straggler).  ``slowdowns`` are ``(t, replica_id, factor)``
    — from ``t`` on, the replica's batches cost ``factor``x the measured
    stage cost (a straggler)."""
    kills: tuple = ()
    slowdowns: tuple = ()

    @classmethod
    def seeded(cls, seed: int, n_replicas: int, horizon: float, *,
               n_kills: int = 1, n_slowdowns: int = 1,
               factor_range=(2.5, 4.0)) -> 'ChaosPlan':
        """A reproducible plan over the trace: busy-replica kills late in
        the arrival window (the backlog is deepest there, so every
        replica has work in flight), slowdowns on a concrete replica
        early (so the straggler lands slow batches — and gets flagged —
        well before the kill)."""
        rng = np.random.default_rng(seed)
        kills = tuple(
            (float(rng.uniform(0.6, 0.9) * horizon), None)
            for _ in range(n_kills))
        slowdowns = tuple(
            (float(rng.uniform(0.05, 0.3) * horizon),
             int(rng.integers(n_replicas)),
             float(rng.uniform(*factor_range)))
            for _ in range(n_slowdowns))
        return cls(kills=kills, slowdowns=slowdowns)

    def slow_factor(self, rid: int, now: float) -> float:
        """The replica's current slowdown (max over active events; 1.0 =
        healthy)."""
        return max([f for t, r, f in self.slowdowns
                    if r == rid and now >= t], default=1.0)


@dataclass
class _Replica:
    rid: int
    model: object
    free_at: float = 0.0
    alive: bool = True
    n_batches: int = 0


@dataclass
class _Flight:
    """One dispatched segment batch: executed eagerly, lands at ``t_end``
    on the simulated clock — unless a kill fires first (``t_kill``), in
    which case the output is discarded and the items requeue."""
    seq: int
    replica: _Replica
    k: int
    items: list
    out: object
    t_start: float
    t_end: float
    t_kill: float | None = None

    @property
    def t_land(self) -> float:
        return self.t_end if self.t_kill is None else self.t_kill


class ReplicaPoolScheduler(ContinuousBatchScheduler):
    """See the module docstring.  Inherits the pending-buffer layout,
    landing logic, exit rule, and SLO hooks from
    :class:`~repro.serving.scheduler.ContinuousBatchScheduler`."""

    def __init__(self, model, *, slots=32, threshold=None, stage_costs=None,
                 max_wait=None, slo=None, replicas=2, min_replicas=1,
                 max_replicas=8, spinup=0.0, restore=None,
                 restore_delay=0.0, chaos=None, straggler_threshold=1.5,
                 evict_after=10 ** 9, tracer=None):
        if stage_costs is None:
            raise ValueError(
                'ReplicaPoolScheduler needs stage_costs: the pool is '
                'event-driven on the simulated clock (one host process '
                'cannot run N replicas concurrently for real)')
        super().__init__(model, slots=slots, threshold=threshold,
                         stage_costs=stage_costs, max_wait=max_wait,
                         slo=slo, tracer=tracer)
        if not 1 <= min_replicas <= max_replicas:
            raise ValueError('need 1 <= min_replicas <= max_replicas')
        self.stage_costs = [float(c) for c in stage_costs]
        self.n_init = max(min_replicas, min(replicas, max_replicas))
        self.min_replicas, self.max_replicas = min_replicas, max_replicas
        self.spinup = spinup
        self.restore = restore or (lambda: model)
        self.restore_delay = restore_delay
        self.chaos = chaos or ChaosPlan()
        self.monitor = StragglerMonitor(n_hosts=1,
                                        threshold=straggler_threshold,
                                        evict_after=evict_after)

    # ------------------------------------------------------------ pool ops

    def _spawn(self, model, now, delay=0.0):
        r = _Replica(rid=self._next_rid, model=model,
                     free_at=now + delay)
        self._next_rid += 1
        self.pool.append(r)
        return r

    def _live(self):
        return [r for r in self.pool if r.alive]

    def _failover(self, dead, t, metrics, reason):
        """Replace a dead replica from the chain checkpoint (``restore``
        hook); the replacement joins after ``restore_delay``."""
        dead.alive = False
        repl = self._spawn(self.restore(), t, self.restore_delay)
        metrics.record_event('failover', t, replica=repl.rid,
                             replaced=dead.rid, reason=reason,
                             n_replicas=len(self._live()))
        if self.tracer.enabled:
            self.tracer.add('failover.restore', t, t + self.restore_delay,
                            track=f'replica{repl.rid}',
                            replaced=dead.rid, reason=reason)
        return repl

    def _consume_kills(self, now, flights, metrics):
        """Fire kill events due by ``now``.  A replica with a batch in
        flight dies mid-batch (the flight is marked killed and lands at
        the kill time, requeueing its requests); an idle replica just
        dies.  Either way a replacement is restored from checkpoint."""
        remaining = []
        for t, rid in self._kills:
            if t > now:
                remaining.append((t, rid))
                continue
            if rid is None:                # kill a busy replica: prefer
                busy = sorted(             # one not already slowed
                    (f for f in flights if f.t_kill is None
                     and f.replica.alive and f.t_start <= t < f.t_end),
                    key=lambda f: (self.chaos.slow_factor(
                        f.replica.rid, t) > 1.0, f.replica.rid))
                victim = (busy[0].replica if busy
                          else next(iter(self._live()), None))
            else:
                victim = next((r for r in self.pool
                               if r.rid == rid and r.alive), None)
            if victim is None:             # already dead: consume, ignore
                continue
            rid = victim.rid
            fail = SimulatedFailure(f'replica {rid} lost at t={t:.6f}')
            inflight = next((f for f in flights
                             if f.replica is victim and f.t_kill is None
                             and f.t_start <= t < f.t_end), None)
            if inflight is not None:
                inflight.t_kill = t        # lands as a kill, not a result
            metrics.record_event('kill', t, replica=rid,
                                 mid_batch=inflight is not None,
                                 reason=repr(fail))
            if self.tracer.enabled:
                self.tracer.instant('kill', t, track=f'replica{rid}',
                                    mid_batch=inflight is not None)
            self._failover(victim, t, metrics, reason=repr(fail))
        self._kills = remaining

    def _scale(self, pend, queue, flights, now, metrics):
        """elastic.py's idea at request level: target replica count from
        the work in the system (queued-and-arrived + pending + in flight)
        over the slot geometry."""
        backlog = sum(len(b) for b in pend) + queue.n_ready(now) \
            + sum(len(f.items) for f in flights)
        target = min(self.max_replicas,
                     max(self.min_replicas,
                         math.ceil(backlog / self.slots)))
        live = self._live()
        while len(live) < target:
            r = self._spawn(self.model, now, self.spinup)
            live.append(r)
            metrics.record_event('scale_up', now, replica=r.rid,
                                 n_replicas=len(live), backlog=backlog)
        idle = [r for r in live if r.free_at <= now]
        # retire idle replicas beyond the target: stragglers first, then
        # newest — the provisioned baseline replicas stay stable
        idle.sort(key=lambda r: (not self.monitor.flagged(r.rid), -r.rid))
        while len(live) > max(target, self.min_replicas) and idle:
            r = idle.pop(0)
            r.alive = False
            live.remove(r)
            metrics.record_event('scale_down', now, replica=r.rid,
                                 n_replicas=len(live), backlog=backlog)

    # ----------------------------------------------------------- dispatch

    def _dispatch(self, replica, k, pend, metrics, now):
        """Pop a segment-``k`` batch, execute it eagerly on ``replica``'s
        model, and put the result in flight until ``now + cost`` (cost
        scaled by the replica's current chaos slowdown)."""
        items = [pend[k].popleft()
                 for _ in range(min(len(pend[k]), self.slots))]
        if k == 0:
            for req, *_ in items:
                req.t_start = now
            if self.tracer.enabled:
                self._trace_dispatch(items, now)
        batch = _gather_rows([(src, idx) for _, src, idx, *_ in items],
                             self.slots)
        out = jax.block_until_ready(replica.model.run_stage(k, batch))
        slow = self.chaos.slow_factor(replica.rid, now)
        cost = self.stage_costs[k] * slow
        fl = _Flight(seq=self._seq, replica=replica, k=k, items=items,
                     out=out, t_start=now, t_end=now + cost)
        self._seq += 1
        replica.free_at = fl.t_end
        replica.n_batches += 1
        return fl

    def _land_flight(self, fl, pend, queue, completions, metrics):
        """A flight reaches its land time: killed flights requeue their
        requests (carry intact — the re-run is bit-exact); successful
        flights complete/promote exactly like the single-executor path,
        then feed the straggler monitor."""
        t = fl.t_land
        track = f'replica{fl.replica.rid}'
        if fl.t_kill is not None:
            if self.tracer.enabled:    # the truncated execution: no result
                self.tracer.add('stage.exec', fl.t_start, t, track=track,
                                stage=fl.k, live=len(fl.items),
                                slots=self.slots, killed=True,
                                rids=[it[0].rid for it in fl.items])
            for item in reversed(fl.items):
                req = item[0]
                if fl.k == 0:
                    req.t_start = None     # service restarts from scratch
                    req.t_enqueued = t     # next queue span opens here
                    queue.requeue(req)
                else:
                    pend[fl.k].appendleft(item)
            return
        if self.tracer.enabled:
            self.tracer.add('stage.exec', fl.t_start, fl.t_end, track=track,
                            stage=fl.k, live=len(fl.items),
                            slots=self.slots,
                            rids=[it[0].rid for it in fl.items])
        metrics.record_batch(fl.k, len(fl.items), self.slots,
                             t=fl.t_start, cost=fl.t_end - fl.t_start)
        self._land(fl.k, fl.items, fl.out, t, pend, completions, metrics,
                   track=track)
        expected = self.stage_costs[fl.k]
        ratio = (fl.t_end - fl.t_start) / max(expected, 1e-12)
        for action, rid in self.monitor.observe_one(fl.replica.rid, ratio):
            if action == 'flag':
                metrics.record_event('straggler_flag', t, replica=rid,
                                     ratio=round(ratio, 3))
            elif action == 'evict' and fl.replica.alive:
                fl.replica.alive = False
                repl = self._spawn(self.model, t, self.spinup)
                metrics.record_event('evict', t, replica=rid,
                                     replaced_by=repl.rid,
                                     n_replicas=len(self._live()))

    def _pool_degrade(self, pend, now, horizon, completions, metrics):
        """SLO sweep before the clock advances to ``horizon``: any pending
        deadline that could not be served even by starting at ``horizon``
        resolves NOW (degraded past segment 0, rejected at segment 0) —
        at ``now``, which is still within its budget."""
        charge = horizon - now
        for j, buf in enumerate(pend):
            kept = deque()
            for item in buf:
                req = item[0]
                if req.deadline is None or self.slo.affordable(
                        req.deadline, now, j, charge, in_batch=False):
                    kept.append(item)
                elif j == 0:
                    self.slo.n_rejected += 1
                    metrics.record_rejection(req.rid, now, 'missed',
                                             t_arrival=req.t_arrival)
                else:
                    self.slo.n_degraded += 1
                    self._complete(req, item[4], item[3], now, completions,
                                   metrics, degraded=True)
            buf.clear()
            buf.extend(kept)

    def _dispatch_filter(self, k, pend, now, cost, completions, metrics):
        """Pre-dispatch SLO filter on the batch about to fly: an item that
        would land past its deadline (exact — the flight cost, slowdown
        included, is known) degrades/rejects instead of flying."""
        kept = deque()
        for item in pend[k]:
            req = item[0]
            if req.deadline is None or len(kept) >= self.slots or \
                    self.slo.affordable(req.deadline, now, k, cost,
                                        in_batch=True):
                kept.append(item)
            elif k == 0:
                self.slo.n_rejected += 1
                metrics.record_rejection(req.rid, now, 'missed',
                                         t_arrival=req.t_arrival)
            else:
                self.slo.n_degraded += 1
                self._complete(req, item[4], item[3], now, completions,
                               metrics, degraded=True)
        pend[k].clear()
        pend[k].extend(kept)

    # ---------------------------------------------------------- event loop

    def run_trace(self, requests):
        """Event-driven serve of a whole arrival trace over the pool;
        returns ``({rid: Completion}, ServingMetrics)``."""
        queue = RequestQueue(requests)
        pend = [deque() for _ in range(self.n_segs)]
        completions, metrics = {}, ServingMetrics()
        self.pool, self._next_rid, self._seq = [], 0, 0
        self._last_depth = None
        self._kills = sorted(self.chaos.kills)
        flights = []
        now = queue.next_arrival() or 0.0
        for _ in range(self.n_init):
            self._spawn(self.model, now)
        metrics.record_event('pool_start', now,
                             n_replicas=len(self._live()))

        while queue or any(pend) or flights:
            self._consume_kills(now, flights, metrics)
            # land due flights in event order (kills land at t_kill)
            due = sorted((f for f in flights if f.t_land <= now),
                         key=lambda f: (f.t_land, f.seq))
            for fl in due:
                flights.remove(fl)
                self._land_flight(fl, pend, queue, completions, metrics)
            if not (queue or any(pend) or flights):
                break                      # landing drained the last work
            # admit arrivals up to the pool's buffering capacity
            cap = self.slots * max(len(self._live()), 1) - len(pend[0])
            for r in queue.pop_ready(now, max(cap, 0)):
                if self._admit(r, now, pend, metrics):
                    pend[0].append((r, r.x, None, None, None))
            depth = len(pend[0]) + queue.n_ready(now)
            if depth != getattr(self, '_last_depth', None):
                metrics.record_gauge('queue_depth', now, depth)
                self._last_depth = depth
            self._scale(pend, queue, flights, now, metrics)
            # dispatch: healthy free replicas first, stragglers last
            free = sorted((r for r in self._live() if r.free_at <= now),
                          key=lambda r: (self.monitor.flagged(r.rid),
                                         r.rid))
            dispatched = False
            for replica in free:
                more = bool(queue) or bool(flights)
                k = self._pick(pend, more_arrivals=more, now=now)
                if self.slo is not None:
                    urgent = self.slo.urgent_segment(pend, now)
                    if urgent is not None:
                        k = urgent
                if k is None:
                    break
                if self.slo is not None:
                    cost = self.stage_costs[k] * self.chaos.slow_factor(
                        replica.rid, now)
                    self._dispatch_filter(k, pend, now, cost, completions,
                                          metrics)
                    if not pend[k]:
                        continue
                flights.append(self._dispatch(replica, k, pend, metrics,
                                              now))
                dispatched = True
            if dispatched:
                continue                   # new flights may land instantly
            # idle: advance to the next event
            horizons = [f.t_land for f in flights]
            horizons += [t for t, _ in self._kills]
            nxt = queue.next_arrival()
            if nxt is not None:
                horizons.append(nxt)
            if any(pend):
                horizons += [r.free_at for r in self._live()
                             if r.free_at > now]
                if self.max_wait is not None:
                    oldest = min(p[0][0].t_arrival for p in pend if p)
                    horizons.append(oldest + self.max_wait)
            if self.slo is not None:
                wake = self.slo.wake(pend, now)
                if wake is not None:
                    horizons.append(wake)
            horizons = [h for h in horizons if h > now]
            if not horizons:
                raise RuntimeError(
                    'replica pool stalled: pending work but no future '
                    'event (this is a scheduler bug); '
                    f'now={now} pend={[len(b) for b in pend]} '
                    f'queue={len(queue)} flights={len(flights)} '
                    f'live={[(r.rid, r.free_at) for r in self._live()]}')
            horizon = min(horizons)
            if self.slo is not None:
                self._pool_degrade(pend, now, horizon, completions,
                                   metrics)
            now = horizon
        return completions, metrics
