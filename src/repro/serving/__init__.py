"""Request-level serving runtime over exported ServingModels.

Where the compression chain's output meets traffic: a time-gated request
queue (``request.py``), a continuous-batching scheduler that compacts
early-exited slots and backfills from the queue (``scheduler.py``), an
SLO layer for deadline admission and graceful degradation through the
exit heads (``slo.py``), an elastic replica pool with straggler
de-prioritization and chaos-tested checkpoint-backed failover
(``replica.py``), a registry that loads and restores models from chain
checkpoints (``registry.py``), and the latency/throughput/occupancy/SLO/
resilience metrics layer (``metrics.py``).  Driven by
``launch/serve_cnn.py --server`` and benchmarked (static batching vs
early-exit compaction under a Poisson trace; ``--chaos`` for the
resilience run) by ``benchmarks/serving_load.py``.  Pipeline-parallel
multi-device serving (``placement.py``) packs each model's stages onto
devices with a greedy-LPT cost solver and streams the int8 carry across
stage boundaries; benchmarked by ``benchmarks/serving_pipeline.py``.
See ``README.md`` in this package for the scheduler contract, failure
model, and placement contract.
"""
from repro.serving.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serving.placement import (Placement,  # noqa: F401
                                     PipelineParallelScheduler, lpt_ratio,
                                     pipeline_devices, solve_placement)
from repro.serving.registry import ModelRegistry  # noqa: F401
from repro.serving.replica import (ChaosPlan,  # noqa: F401
                                   ReplicaPoolScheduler)
from repro.serving.request import (Completion, Request,  # noqa: F401
                                   RequestQueue)
from repro.serving.scheduler import (ContinuousBatchScheduler,  # noqa: F401
                                     StaticBatchScheduler, exit_decisions)
from repro.serving.slo import SLOPolicy  # noqa: F401
