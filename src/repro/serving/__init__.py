"""Request-level serving runtime over exported ServingModels.

Where the compression chain's output meets traffic: a time-gated request
queue (``request.py``), a continuous-batching scheduler that compacts
early-exited slots and backfills from the queue (``scheduler.py``), a
checkpoint-backed model registry (``registry.py``), and the latency/
throughput/occupancy metrics layer (``metrics.py``).  Driven by
``launch/serve_cnn.py --server`` and benchmarked (static batching vs
early-exit compaction under a Poisson trace) by
``benchmarks/serving_load.py``.
"""
from repro.serving.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serving.registry import ModelRegistry  # noqa: F401
from repro.serving.request import (Completion, Request,  # noqa: F401
                                   RequestQueue)
from repro.serving.scheduler import (ContinuousBatchScheduler,  # noqa: F401
                                     StaticBatchScheduler, exit_decisions)
