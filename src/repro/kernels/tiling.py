"""Shared block-fitting for the Pallas kernels (one copy, not N).

Every kernel here tiles a dim into equal blocks, so the block size must
divide the dim.  The old per-kernel ``_fit`` silently decremented the block
until it divided — for a prime dim that degrades to block size 1, which on
TPU is catastrophic (1-wide MXU/VPU tiles).  The shared policy:

* :func:`fit_block` returns the largest divisor <= the requested block, but
  *raises* once the best divisor drops below ``floor`` instead of silently
  emitting sliver tiles.
* :func:`pad_to` gives the next multiple of 128 (the TPU lane width);
  kernel entry points zero-pad awkward dims up to it and slice the result
  back, so callers never see the error for value-preserving paddings.
"""
from __future__ import annotations

LANE = 128          # TPU lane width: last-dim tiles are always 128 wide
SUBLANE = 8         # TPU sublane width: second-minor tiles pack 8 rows

# THE shared VMEM budget every kernel in this package sizes its resident
# blocks against (conservative half of a v5e core's ~16 MiB, leaving room
# for double buffering).  One constant, not N per-kernel copies: a kernel
# that needs operands resident across grid steps (lowrank_conv's v block,
# fake_quant's fused column stripe, depthwise_conv's spatial plane) checks
# against this and falls back / grids further instead of silently spilling.
VMEM_BUDGET = 8 * 2 ** 20


def pad_to(dim: int, mult: int = LANE) -> int:
    """Next multiple of ``mult`` >= dim (dim itself when it already is)."""
    return -(-dim // mult) * mult


def batch_slots(n: int, mult: int = SUBLANE) -> int:
    """Serving batch geometry: the slot count for ``n`` concurrent requests.

    The im2col int8 matmuls tile M = B*OH*OW, so the batch dim lands on the
    sublane axis — a batch that is a multiple of 8 keeps every M tile
    rectangular.  The request batcher (repro/serving/) pads its slot count
    up to this and keeps it FIXED across rounds: one compiled program per
    stage (no per-occupancy retraces), and per-slot results independent of
    how the other slots are filled (the scheduler's bit-exactness
    contract).
    """
    return pad_to(max(int(n), 1), mult)


def fit_block(block: int, dim: int, *, floor: int = 8) -> int:
    """Largest divisor of ``dim`` that is <= ``block``.

    Raises ValueError when the best divisor is smaller than
    ``min(floor, dim)`` — e.g. prime dims, where the old behaviour silently
    degraded to 1-wide blocks.  Callers should pad the dim to
    ``pad_to(dim)`` first (the kernel wrappers in this package do).
    """
    if dim <= 0:
        raise ValueError(f'cannot tile empty dim {dim}')
    b = min(block, dim)
    while dim % b:
        b -= 1
    if b < min(floor, dim):
        raise ValueError(
            f'no usable block <= {block} for dim {dim} (best divisor {b}); '
            f'pad the dim to {pad_to(dim)} (next multiple of {LANE})')
    return b


def fit_or_pad(block: int, dim: int, *, floor: int = 8) -> tuple[int, int]:
    """(block, padded_dim): like :func:`fit_block`, but instead of raising,
    returns the block for the 128-padded dim (padded_dim == dim when the
    original dim already tiles cleanly)."""
    try:
        return fit_block(block, dim, floor=floor), dim
    except ValueError:
        p = pad_to(dim)
        return fit_block(block, p, floor=floor), p
