"""Direct (non-im2col) Pallas depthwise/grouped conv kernel — the serving
realization of the Q pass for MobileNet's depthwise layers.

A depthwise conv is block-diagonal in im2col form: routing it through the
int8 matmul tiles would waste ~CIN x of every MXU tile, which is why the
serving path previously *fell back* to a dequantized ``lax.conv`` for
grouped convs — leaving ~21% of MobileNet's MACs in fp32
(``ServingModel.summary()`` ``fallback_mac_fraction``).  This kernel kills
that fallback with the operation's natural lowering: per-channel int8
multiply-accumulates over the KH x KW spatial window on the VPU (channels
on the 128 lane axis, no patch materialization, no MXU), with the shared
requantize epilogue — int32 accumulator -> static scale -> int8 out — so
depthwise layers are int8-in / int8-out in HBM like every other layer.

Lowering: the input is SAME-padded outside the kernel (symmetric
quantization has zero-point 0, so the int8 zero padding is value-exact) and
channels are padded to the 128 lane.  Grouped convs with per-group input
depth 1 — i.e. ``groups == CIN`` with any channel multiplier — are served
by expanding the input channel axis to the output channels
(``x_e[..., o] = x[..., o // mult]``, a pure int8 memory-layout op);
per-group depth > 1 has no per-channel lowering and stays on the declared
fallback (no such layer exists in this repo's families).  Grid is
``(B, COUT/bc)``: each step holds one padded spatial plane
``(HP, WP, bc)`` in VMEM, unrolls the KH*KW taps as strided-slice
multiply-accumulates into an int32 register tile, and runs the epilogue
once — one kernel launch per layer, zero accumulator traffic to HBM.

Bit-exactness contract (tested): the int32 accumulation is exact, and the
fp32 epilogue op order (``acc * (sx * sw) + b``, ReLU, requantize) matches
``ref.depthwise_conv_ref`` — which accumulates exactly via ``lax.conv`` on
the raw integer codes — so kernel and oracle agree bit-for-bit, not just
allclose (depthwise sums of <= KH*KW*127^2 stay far below 2^24).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANE, VMEM_BUDGET, pad_to


def fits_depthwise(w_shape) -> bool:
    """Can this grouped conv serve on the depthwise kernel?

    True for per-group input depth 1 (HWIO weight ``(KH, KW, 1, COUT)``,
    the ``groups == CIN`` family — plain depthwise and channel-multiplier
    variants).  Generic grouped convs (per-group depth > 1) keep the
    declared fallback; none exist in this repo's model families.
    """
    return len(w_shape) == 4 and w_shape[2] == 1


def _same_pads(h: int, w: int, kh: int, kw: int, stride: int):
    """SAME-padding geometry (identical to quant_conv's im2col plan and
    lax.conv 'SAME'): returns ((top, bottom), (left, right), oh, ow)."""
    oh, ow = -(-h // stride), -(-w // stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - w, 0)
    return ((pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2), oh, ow)


def _dw_kernel(x_ref, w_ref, sx_ref, sw_ref, b_ref, o_ref, *, kh, kw,
               stride, oh, ow, relu, out_scale, out_qmax):
    x = x_ref[0]                                     # (HP, WP, bc) int8
    acc = jnp.zeros(o_ref.shape[1:], jnp.int32)      # (OH, OW, bc) registers
    for i in range(kh):                              # unrolled taps: the
        for j in range(kw):                          # whole window sum is
            win = jax.lax.slice(                     # per-channel VPU FMAs
                x, (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1,
                 x.shape[-1]),
                (stride, stride, 1))
            acc += win.astype(jnp.int32) * w_ref[i * kw + j].astype(
                jnp.int32)[None, None, :]
    # shared epilogue, same fp32 op order as quant_matmul's: dequant on the
    # (sx * sw) product, bias, ReLU, optional static requantize to int8
    y = acc.astype(jnp.float32) * (sx_ref[0] * sw_ref[...])[None, None, :]
    y = y + b_ref[...][None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    if out_scale is not None:
        y = jnp.clip(jnp.round(y / out_scale), -out_qmax - 1.0, out_qmax)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    'stride', 'relu', 'bc', 'out_dtype', 'interpret', 'out_scale',
    'out_qmax'))
def depthwise_conv(x_q, w_q, sx, sw, bias=None, *, stride=1, relu=False,
                   bc=LANE, out_dtype=jnp.float32, interpret=False,
                   out_scale=None, out_qmax=127.0):
    """Int8 NHWC depthwise/grouped conv, direct (non-im2col) Pallas lowering.

    x_q: int8 (B,H,W,CIN); w_q: int8 (KH,KW,1,COUT) with COUT an integer
    multiple of CIN (the channel multiplier; COUT == CIN is plain
    depthwise); sx: scalar fp32 per-tensor activation scale (static float
    or traced scalar — it rides as a (1,) operand, not a trace constant);
    sw: (COUT,) fp32 static per-channel weight scales; bias: (COUT,) fp32
    or None.  Returns (B,OH,OW,COUT) ``out_dtype``, or int8 when the
    ``out_scale`` requantize epilogue is selected (cf. quant_matmul).
    """
    B, H, W, C = x_q.shape
    kh, kw, cg, n = w_q.shape
    assert cg == 1, f'per-group input depth must be 1, got {cg}'
    assert n % C == 0, (n, C)
    mult = n // C
    if mult > 1:        # channel multiplier: output channel o reads o//mult
        x_q = jnp.repeat(x_q, mult, axis=-1)
    (ph, pw, oh, ow) = _same_pads(H, W, kh, kw, stride)
    x_q = jnp.pad(x_q, ((0, 0), ph, pw, (0, 0)))
    np_ = pad_to(n)
    bc = min(bc, np_)
    if np_ != n:
        x_q = jnp.pad(x_q, ((0, 0), (0, 0), (0, 0), (0, np_ - n)))
    hp, wp = x_q.shape[1], x_q.shape[2]
    assert (hp * wp + 4 * oh * ow + 4 * oh * ow) * bc <= VMEM_BUDGET, \
        (hp, wp, bc)
    w2 = jnp.pad(w_q.reshape(kh * kw, n), ((0, 0), (0, np_ - n)))
    sw = jnp.pad(sw.astype(jnp.float32), (0, np_ - n))
    b = (jnp.zeros((n,), jnp.float32) if bias is None
         else bias.astype(jnp.float32))
    b = jnp.pad(b, (0, np_ - n))
    if out_scale is not None:
        out_scale, out_dtype = float(out_scale), jnp.int8
    out = pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride, oh=oh,
                          ow=ow, relu=relu, out_scale=out_scale,
                          out_qmax=float(out_qmax)),
        grid=(B, np_ // bc),
        in_specs=[
            pl.BlockSpec((1, hp, wp, bc), lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((kh * kw, bc), lambda b, c: (0, c)),
            pl.BlockSpec((1,), lambda b, c: (0,)),
            pl.BlockSpec((bc,), lambda b, c: (c,)),
            pl.BlockSpec((bc,), lambda b, c: (c,)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, oh, ow, np_), out_dtype),
        interpret=interpret,
    )(x_q, w2, jnp.reshape(jnp.asarray(sx, jnp.float32), (1,)), sw, b)
    return out[..., :n]
