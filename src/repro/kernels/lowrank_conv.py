"""Fused low-rank conv kernel — a factored (u, v) conv pair in ONE Pallas
launch (the serving realization of the chain's L∘Q composition).

The 'L' pass (core/lowrank.py) splits a conv (KH,KW,CIN,COUT) into a
spatial conv down to rank ``r`` ('u') chained with a 1x1 conv back up
('v').  Served naively that is two kernel launches with an
(B,OH,OW,r) int8 intermediate bouncing through HBM — and because the rank
bottleneck usually has r < 128, the second matmul wastes most of each
128-wide MXU tile on the K axis.  This kernel fuses the pair:

    patches (M, K1) @ u_q (K1, Rp)   -> int32 acc     (K1 grid axis)
    requantize(acc * sx*su + bu) / h_scale -> int8 h  (VMEM scratch only)
    h (bm, Rp) @ v_q (Rp, N)         -> int32         (single MXU dot)
    dequant + bias (+ReLU) (+requantize)              (epilogue)

The r-dim intermediate lives entirely in VMEM scratch, zero-padded to the
128 lane when r < 128 — padded u columns are zero int8, so the padded
intermediate quantizes to exactly 0 and contributes nothing to the second
matmul (padding is value-exact, and the whole launch is **bit-exact** with
the chained quant_conv(u, out_scale=h_scale) → quant_conv(v) path: the
int32 accumulation domains and the fp32 epilogue op order are identical).

Grid is (M/bm, K1/bk); the COUT axis is served as one lane-padded block —
v_q (Rp, Np), the scales and the (bm, Np) output tile all fit VMEM
comfortably for CNN-scale widths (Np <= ~2048).  ``lowrank_conv`` asserts
that budget instead of silently spilling; the layer-plan compiler
(core/export.py) falls back to the chained path for larger layers or
r > 128.

All activation scales here are **static** Python floats captured at export
calibration — no abs-max pass ever reads the activation tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_conv import im2col_nhwc
from repro.kernels.tiling import fit_or_pad, pad_to

# conservative VMEM ceiling for the non-gridded (Rp, Np)/(bm, Np) operands
_VMEM_BYTES = 8 * 2 ** 20


def fits_fused(r: int, cout: int, *, bm: int = 128) -> bool:
    """Can a factored (u, v) pair with this rank/width serve as ONE launch?

    True when the lane-padded rank fits a single 128-wide K tile (the
    bit-exactness envelope) and the whole-COUT v block + output tile fit
    the VMEM budget.  The layer-plan compiler (core/export.py) chains the
    two kernels when this is False.
    """
    rp, np_ = pad_to(r), pad_to(cout)
    return (rp <= 128 and rp <= _VMEM_BYTES // 4 // bm
            and (rp * np_ + 4 * bm * np_) <= _VMEM_BYTES)


def _lr_kernel(x_ref, u_ref, su_ref, bu_ref, v_ref, sv_ref, bv_ref, o_ref,
               acc_ref, *, n_k, sx, h_scale, h_qmax, relu, out_scale,
               out_qmax):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], u_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _done():
        # u epilogue: dequant + bias, then static requantize to int8 — the
        # same fp32 op order as quant_matmul's epilogue, so the fused and
        # chained paths agree bit-for-bit.
        h = acc_ref[...].astype(jnp.float32) * (sx * su_ref[...][None, :])
        h = h + bu_ref[...][None, :]
        h_q = jnp.clip(jnp.round(h / h_scale), -h_qmax - 1.0,
                       h_qmax).astype(jnp.int8)
        # v stage: the rank-dim matmul never leaves VMEM
        acc2 = jax.lax.dot_general(
            h_q, v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        y = acc2.astype(jnp.float32) * (h_scale * sv_ref[...][None, :])
        y = y + bv_ref[...][None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        if out_scale is not None:
            y = jnp.clip(jnp.round(y / out_scale), -out_qmax - 1.0, out_qmax)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    'sx', 'h_scale', 'stride', 'relu', 'bm', 'bk', 'out_dtype', 'interpret',
    'out_scale', 'h_qmax', 'out_qmax'))
def lowrank_conv(x_q, u_q, v_q, su, sv, bu, bv, *, sx, h_scale, stride=1,
                 relu=False, bm=128, bk=256, out_dtype=jnp.float32,
                 interpret=False, out_scale=None, h_qmax=127.0,
                 out_qmax=127.0):
    """One-launch factored conv: x_q int8 (B,H,W,CIN) -> (B,OH,OW,COUT).

    u_q int8 (KH,KW,CIN,R); v_q int8 (1,1,R,COUT) (or (R,COUT)); su (R,) /
    sv (COUT,) static per-channel weight scales; bu (R,) / bv (COUT,) fp32
    biases (pass zeros when absent).  ``sx`` / ``h_scale`` / ``out_scale``
    are *static* Python floats: the input activation scale, the rank-
    intermediate requantize scale, and (optionally) the int8 output scale.
    """
    B, H, W, C = x_q.shape
    kh, kw, c2, r = u_q.shape
    assert C == c2, (C, c2)
    v_q = v_q.reshape(v_q.shape[-2], v_q.shape[-1])
    r2, n = v_q.shape
    assert r == r2, (r, r2)
    patches, (oh, ow) = im2col_nhwc(x_q, kh, kw, stride)
    m = B * oh * ow
    k1 = kh * kw * C

    (bm, mp), (bk, k1p) = fit_or_pad(bm, m), fit_or_pad(bk, k1)
    rp, np_ = pad_to(r), pad_to(n)
    assert rp <= _VMEM_BYTES // 4 // bm, (rp, bm)
    assert (rp * np_ + 4 * bm * np_) <= _VMEM_BYTES, (rp, np_, bm)
    if (mp, k1p) != (m, k1):
        patches = jnp.pad(patches, ((0, mp - m), (0, k1p - k1)))
    u2 = jnp.pad(u_q.reshape(k1, r), ((0, k1p - k1), (0, rp - r)))
    v2 = jnp.pad(v_q, ((0, rp - r), (0, np_ - n)))
    su = jnp.pad(su.astype(jnp.float32), (0, rp - r))
    bu = jnp.pad(bu.astype(jnp.float32), (0, rp - r))
    sv = jnp.pad(sv.astype(jnp.float32), (0, np_ - n))
    bv = jnp.pad(bv.astype(jnp.float32), (0, np_ - n))

    n_k = k1p // bk
    grid = (mp // bm, n_k)
    if out_scale is not None:
        out_scale, out_dtype = float(out_scale), jnp.int8
    out = pl.pallas_call(
        functools.partial(_lr_kernel, n_k=n_k, sx=float(sx),
                          h_scale=float(h_scale), h_qmax=float(h_qmax),
                          relu=relu, out_scale=out_scale,
                          out_qmax=float(out_qmax)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, rp), lambda i, k: (k, 0)),
            pl.BlockSpec((rp,), lambda i, k: (0,)),
            pl.BlockSpec((rp,), lambda i, k: (0,)),
            pl.BlockSpec((rp, np_), lambda i, k: (0, 0)),
            pl.BlockSpec((np_,), lambda i, k: (0,)),
            pl.BlockSpec((np_,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, np_), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, rp), jnp.int32)],
        interpret=interpret,
    )(patches, u2, su, bu, v2, sv, bv)
    return out[:m, :n].reshape(B, oh, ow, n)
