"""Fused low-rank conv kernel — a factored (u, v) conv pair in ONE Pallas
launch (the serving realization of the chain's L∘Q composition).

The 'L' pass (core/lowrank.py) splits a conv (KH,KW,CIN,COUT) into a
spatial conv down to rank ``r`` ('u') chained with a 1x1 conv back up
('v').  Served naively that is two kernel launches with an
(B,OH,OW,r) int8 intermediate bouncing through HBM — and because the rank
bottleneck usually has r < 128, the second matmul wastes most of each
128-wide MXU tile on the K axis.  This kernel fuses the pair:

    patches (M, K1) @ u_q (K1, Rp)   -> int32 acc     (K1 grid axis)
    requantize(acc * sx*su + bu) / h_scale -> int8 h  (VMEM scratch only)
    h (bm, Rp) @ v_q (Rp, bn)        -> int32         (per COUT tile)
    dequant + bias (+ReLU) (+requantize)              (epilogue)

The r-dim intermediate lives entirely in VMEM scratch, zero-padded to the
128 lane when r < 128 — padded u columns are zero int8, so the padded
intermediate quantizes to exactly 0 and contributes nothing to the second
matmul (padding is value-exact, and the whole launch is **bit-exact** with
the chained quant_conv(u, out_scale=h_scale) → quant_conv(v) path: the
int32 accumulation domains and the fp32 epilogue op order are identical).

Grid is (M/bm, K1/bk, N/bn) with the COUT axis innermost: the u-stage
operands (patches block, u block) are indexed by (i, k) only, so they are
fetched once per K step and never re-streamed while the N axis cycles; the
int8 ``h`` scratch persists across N tiles, so the v stage is one
(bm, Rp) x (Rp, bn) dot per COUT tile with zero recompute.  That removes
the old whole-width (Rp, Np) v block and its VMEM assert — any COUT now
fits (``fits_fused`` keeps only the rank envelope).  The one cost of this
grid order: the (bm, bn) output block is revisited (and flushed) once per
K step but only written on the last, so fused output traffic is n_k x the
chained path's — ``lowering_costs`` below charges exactly that, and the
layer-plan compiler (core/export.py) picks fused vs chained per layer from
it instead of assuming fused always wins.

All activation scales here are **static** Python floats captured at export
calibration — no abs-max pass ever reads the activation tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_conv import im2col_nhwc
from repro.kernels.tiling import VMEM_BUDGET, fit_or_pad, pad_to

# Serving-cost constants for lowering_costs (TPU v5e, cf. benchmarks/
# roofline.py): int8 MXU peak 394 TOP/s = 197e6 MACs/us, HBM 819 GB/s =
# 819e3 bytes/us, and ~2us of per-launch dispatch overhead — the term the
# two-launch chained path pays twice.
MACS_PER_US = 197e6
BYTES_PER_US = 819e3
LAUNCH_US = 2.0


def fits_fused(r: int, cout: int, *, bm: int = 128) -> bool:
    """Can a factored (u, v) pair with this rank/width serve as ONE launch?

    True when the lane-padded rank fits a single 128-wide K tile for the v
    matmul — the bit-exactness envelope (one int32 dot over the whole rank,
    the same accumulation domain as the chained path's single K tile).
    COUT no longer matters: the N axis is a grid dimension, so any width
    streams through (bm, bn) tiles against the persistent h scratch.  The
    layer-plan compiler (core/export.py) chains the two kernels when this
    is False — and even when it is True, picks fused vs chained by
    :func:`lowering_costs`, not by fiat.
    """
    del cout, bm   # kept for API compat: width/M-tile no longer constrain
    return pad_to(r) <= 128


def lowering_costs(m: int, k1: int, r: int, n: int, *, bm: int = 128,
                   bk: int = 256, bn: int = 128) -> dict:
    """Analytic cost (us) of serving one factored conv fused vs chained.

    Models the exact block geometry both lowerings run (same fit_or_pad /
    pad_to tiling as the kernels): MAC count is identical, so the decision
    is traffic + launches.  Fused pays n_k spurious output flushes (the
    (bm, bn) block is revisited per K step, written only on the last) but
    streams the u-stage operands once and never round-trips h through HBM;
    chained pays a second launch and the (M, Rp) h write+read but flushes
    each output block exactly once.  Per-launch time is the roofline max of
    its compute and traffic terms; the chained total is the sum of its two
    launches.  Used by core/export.py ``select_kernels='model'`` (the
    default) — 'measure' mode times the two lowerings instead.
    """
    (bm, mp), (bk, k1p) = fit_or_pad(bm, m), fit_or_pad(bk, k1)
    (bn, np_) = fit_or_pad(bn, n)
    rp = pad_to(r)
    n_m, n_k, n_n = mp // bm, k1p // bk, np_ // bn
    macs_u = mp * k1p * rp          # padded-domain MACs, what the MXU runs
    macs_v = mp * rp * np_
    fused_bytes = (mp * k1p              # patches: once per (i, k), N inner
                   + n_m * k1p * rp     # u re-streamed per M tile
                   + n_m * rp * np_     # v re-streamed per M tile
                   + n_k * mp * np_)    # output flushed once per K revisit
    chained_bytes_u = mp * k1p + n_m * k1p * rp + mp * rp
    chained_bytes_v = mp * rp + n_m * rp * np_ + mp * np_
    fused_us = LAUNCH_US + max((macs_u + macs_v) / MACS_PER_US,
                               fused_bytes / BYTES_PER_US)
    chained_us = (2 * LAUNCH_US
                  + max(macs_u / MACS_PER_US, chained_bytes_u / BYTES_PER_US)
                  + max(macs_v / MACS_PER_US, chained_bytes_v / BYTES_PER_US))
    return {'fused_us': fused_us, 'chained_us': chained_us,
            'fused_bytes': fused_bytes,
            'chained_bytes': chained_bytes_u + chained_bytes_v,
            'macs': macs_u + macs_v}


def _lr_kernel(x_ref, u_ref, su_ref, bu_ref, v_ref, sv_ref, bv_ref, o_ref,
               acc_ref, hq_ref, *, n_k, sx, h_scale, h_qmax, relu, out_scale,
               out_qmax):
    k = pl.program_id(1)
    n = pl.program_id(2)

    @pl.when((k == 0) & (n == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(n == 0)   # u-stage accumulation: once per K step, not per tile
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], u_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when((k == n_k - 1) & (n == 0))
    def _requant():
        # u epilogue: dequant + bias, then static requantize to int8 — the
        # same fp32 op order as quant_matmul's epilogue, so the fused and
        # chained paths agree bit-for-bit.  h persists in scratch across
        # the whole N sweep.
        h = acc_ref[...].astype(jnp.float32) * (sx * su_ref[...][None, :])
        h = h + bu_ref[...][None, :]
        hq_ref[...] = jnp.clip(jnp.round(h / h_scale), -h_qmax - 1.0,
                               h_qmax).astype(jnp.int8)

    @pl.when(k == n_k - 1)
    def _vstage():
        # v stage, one COUT tile: the rank-dim matmul never leaves VMEM
        acc2 = jax.lax.dot_general(
            hq_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        y = acc2.astype(jnp.float32) * (h_scale * sv_ref[...][None, :])
        y = y + bv_ref[...][None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        if out_scale is not None:
            y = jnp.clip(jnp.round(y / out_scale), -out_qmax - 1.0, out_qmax)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    'sx', 'h_scale', 'stride', 'relu', 'bm', 'bk', 'bn', 'out_dtype',
    'interpret', 'out_scale', 'h_qmax', 'out_qmax'))
def lowrank_conv(x_q, u_q, v_q, su, sv, bu, bv, *, sx, h_scale, stride=1,
                 relu=False, bm=128, bk=256, bn=128, out_dtype=jnp.float32,
                 interpret=False, out_scale=None, h_qmax=127.0,
                 out_qmax=127.0):
    """One-launch factored conv: x_q int8 (B,H,W,CIN) -> (B,OH,OW,COUT).

    u_q int8 (KH,KW,CIN,R); v_q int8 (1,1,R,COUT) (or (R,COUT)); su (R,) /
    sv (COUT,) static per-channel weight scales; bu (R,) / bv (COUT,) fp32
    biases (pass zeros when absent).  ``sx`` / ``h_scale`` / ``out_scale``
    are *static* Python floats: the input activation scale, the rank-
    intermediate requantize scale, and (optionally) the int8 output scale.
    COUT is gridded in ``bn`` tiles (any width serves); the rank must fit
    one lane tile (``fits_fused``).
    """
    B, H, W, C = x_q.shape
    kh, kw, c2, r = u_q.shape
    assert C == c2, (C, c2)
    v_q = v_q.reshape(v_q.shape[-2], v_q.shape[-1])
    r2, n = v_q.shape
    assert r == r2, (r, r2)
    patches, (oh, ow) = im2col_nhwc(x_q, kh, kw, stride)
    m = B * oh * ow
    k1 = kh * kw * C

    (bm, mp), (bk, k1p) = fit_or_pad(bm, m), fit_or_pad(bk, k1)
    (bn, np_) = fit_or_pad(bn, n)
    rp = pad_to(r)
    assert rp <= 128, (r, 'rank exceeds the fused envelope; chain instead')
    # resident per grid step: x/u/v blocks + int32 acc + int8 h + out tile
    assert (bm * bk + bk * rp + rp * bn + 4 * bm * rp + bm * rp
            + 4 * bm * bn) <= VMEM_BUDGET, (bm, bk, bn, rp)
    if (mp, k1p) != (m, k1):
        patches = jnp.pad(patches, ((0, mp - m), (0, k1p - k1)))
    u2 = jnp.pad(u_q.reshape(k1, r), ((0, k1p - k1), (0, rp - r)))
    v2 = jnp.pad(v_q, ((0, rp - r), (0, np_ - n)))
    su = jnp.pad(su.astype(jnp.float32), (0, rp - r))
    bu = jnp.pad(bu.astype(jnp.float32), (0, rp - r))
    sv = jnp.pad(sv.astype(jnp.float32), (0, np_ - n))
    bv = jnp.pad(bv.astype(jnp.float32), (0, np_ - n))

    n_k = k1p // bk
    grid = (mp // bm, n_k, np_ // bn)
    if out_scale is not None:
        out_scale, out_dtype = float(out_scale), jnp.int8
    out = pl.pallas_call(
        functools.partial(_lr_kernel, n_k=n_k, sx=float(sx),
                          h_scale=float(h_scale), h_qmax=float(h_qmax),
                          relu=relu, out_scale=out_scale,
                          out_qmax=float(out_qmax)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k, j: (i, k)),
            pl.BlockSpec((bk, rp), lambda i, k, j: (k, 0)),
            pl.BlockSpec((rp,), lambda i, k, j: (0,)),
            pl.BlockSpec((rp,), lambda i, k, j: (0,)),
            pl.BlockSpec((rp, bn), lambda i, k, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, k, j: (j,)),
            pl.BlockSpec((bn,), lambda i, k, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, k, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, rp), jnp.int32),
                        pltpu.VMEM((bm, rp), jnp.int8)],
        interpret=interpret,
    )(patches, u2, su, bu, v2, sv, bv)
    return out[:m, :n].reshape(B, oh, ow, n)
