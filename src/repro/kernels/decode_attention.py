"""Flash-decode Pallas kernel: one-token GQA attention over a long cache.

Per (batch, kv-head) grid cell, the query group (g = H/K heads) attends to
the cache in (s_blk, D) VMEM tiles with an online-softmax accumulator in
scratch — O(s_blk·D) VMEM for arbitrarily long caches, the decode-side
analogue of flash attention, tiled so D and s_blk are multiples of 128 for
the MXU.  This is the per-device *local* computation of the sequence-sharded
decode path (the softmax-merge across shards happens in the launcher's
shard_map wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fit(block: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= ``block`` (prefers mult. of 128)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, n_s):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                 # (g, D)
    k = k_ref[0, 0]                                 # (s_blk, D)
    v = v_ref[0, 0]                                 # (s_blk, D)
    scale = q.shape[-1] ** -0.5
    logits = jax.lax.dot_general(
        q.astype(jnp.float32) * scale, k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (g, s_blk)
    logits = jnp.where(valid_ref[...][None, :], logits, NEG_INF)

    m_new = jnp.maximum(m_ref[...], jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _decode_kernel_int8(q_ref, k_ref, v_ref, ks_ref, vs_ref, valid_ref,
                        o_ref, m_ref, l_ref, acc_ref, *, n_s):
    """int8-KV variant: k/v stream from HBM as int8 and dequantize in VMEM
    (per-token scales) — halves the cache-read bytes that dominate
    memory-bound decode (§Perf iteration 7)."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
    scale = q.shape[-1] ** -0.5
    logits = jax.lax.dot_general(
        q.astype(jnp.float32) * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    logits = jnp.where(valid_ref[...][None, :], logits, NEG_INF)
    m_new = jnp.maximum(m_ref[...], jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(
            l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('s_blk', 'interpret'))
def decode_attention_int8(q, k_q, v_q, k_s, v_s, valid, *, s_blk=512,
                          interpret=False):
    """q: (B,H,D); k_q,v_q: int8 (B,S,K,D); k_s,v_s: (B,S,K) fp32 scales."""
    B, H, D = q.shape
    S, K = k_q.shape[1], k_q.shape[2]
    g = H // K
    s_blk = _fit(s_blk, S)
    n_s = S // s_blk
    qg = q.reshape(B, K, g, D)
    kt = k_q.transpose(0, 2, 1, 3)
    vt = v_q.transpose(0, 2, 1, 3)
    kst = k_s.transpose(0, 2, 1)
    vst = v_s.transpose(0, 2, 1)
    out = pl.pallas_call(
        functools.partial(_decode_kernel_int8, n_s=n_s),
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, s_blk, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, s_blk, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, s_blk), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, s_blk), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((s_blk,), lambda b, h, s: (s,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, g, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, D), jnp.float32)],
        interpret=interpret,
    )(qg, kt, vt, kst, vst, valid)
    return out.reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=('s_blk', 'interpret'))
def decode_attention(q, k, v, valid, *, s_blk=512, interpret=False):
    """q: (B,H,D); k,v: (B,S,K,D); valid: (S,) bool. Returns (B,H,D)."""
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    g = H // K
    s_blk = _fit(s_blk, S)
    n_s = S // s_blk
    qg = q.reshape(B, K, g, D)
    kt = k.transpose(0, 2, 1, 3)                    # (B,K,S,D)
    vt = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_s=n_s),
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, s_blk, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, s_blk, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((s_blk,), lambda b, h, s: (s,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, g, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, D), jnp.float32)],
        interpret=interpret,
    )(qg, kt, vt, valid)
    return out.reshape(B, H, D)
