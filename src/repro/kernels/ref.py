"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def requantize(y, out_scale, qmax=127.0):
    """Static requantize: fp32 -> int8 on the ``out_scale`` grid.

    The jnp realization of the kernels' requantize epilogue — same op order
    (divide, round, clip, cast), so ref and Pallas paths agree bit-for-bit
    given bit-equal fp32 inputs.
    """
    return jnp.clip(jnp.round(y / out_scale), -qmax - 1.0,
                    qmax).astype(jnp.int8)


def quant_matmul_ref(x_q, w_q, sx, sw, out_dtype=jnp.float32):
    """int8 x (M,K) @ int8 w (K,N), per-row sx (M,), per-col sw (N,)."""
    acc = jnp.einsum('mk,kn->mn', x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * sx[:, None] * sw[None, :]).astype(out_dtype)


def fake_quant_ref(w, bits: int):
    """Per-output-channel (last dim) symmetric fake quantization."""
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    return jnp.clip(jnp.round(w / scale), -qmax - 1, qmax) * scale


def quant_conv_ref(x_q, w_q, sx, sw, bias=None, *, stride=1, relu=False,
                   groups=1, out_dtype=jnp.float32, out_scale=None,
                   out_qmax=127.0):
    """lax.conv oracle for kernels/quant_conv.quant_conv.

    Dequantizes both operands and runs the SAME-padded fp32 conv — the conv
    is bilinear, so this equals the int8-accumulate + epilogue-rescale path
    up to fp32 rounding.  x_q int8 NHWC, w_q int8 HWIO, sx scalar, sw
    (COUT,).  ``out_scale`` mirrors the kernels' requantize epilogue
    (int8 output on a static grid).
    """
    x = x_q.astype(jnp.float32) * jnp.asarray(sx, jnp.float32)
    w = w_q.astype(jnp.float32) * sw.astype(jnp.float32)[None, None, None, :]
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), 'SAME', feature_group_count=groups,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    if out_scale is not None:
        return requantize(y, out_scale, out_qmax)
    return y.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=('stride', 'relu', 'out_dtype',
                                             'out_scale', 'out_qmax'))
def depthwise_conv_ref(x_q, w_q, sx, sw, bias=None, *, stride=1, relu=False,
                       out_dtype=jnp.float32, out_scale=None, out_qmax=127.0):
    """lax.conv oracle for kernels/depthwise_conv.depthwise_conv, BIT-exact.

    Unlike :func:`quant_conv_ref` (which dequantizes before the conv), this
    accumulates on the *raw integer codes*: fp32 holds every depthwise
    partial sum exactly (<= KH*KW*127^2 << 2^24), so the lax.conv
    accumulation equals the kernel's int32 accumulation bit-for-bit, and
    the epilogue applies the identical fp32 op order — ``acc * (sx * sw)``,
    bias, ReLU, requantize.  x_q int8 (B,H,W,CIN); w_q int8 (KH,KW,1,COUT)
    with COUT a multiple of CIN (feature_group_count = CIN).

    Jitted on purpose: op-by-op dispatch compiles ``acc * scale + bias``
    without the fused multiply-add contraction XLA applies inside a traced
    program, which perturbs the fp32 result by ~1 ulp vs the (also
    compiled) Pallas kernel.  With both sides compiled the contraction is
    identical and the fp32 outputs agree bit-for-bit (the int8
    ``out_scale`` outputs agree either way — rounding absorbs the ulp).
    """
    groups = x_q.shape[-1]
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.float32), w_q.astype(jnp.float32), (stride, stride),
        'SAME', feature_group_count=groups,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    scale = jnp.asarray(sx, jnp.float32) * sw.astype(jnp.float32)
    y = acc * scale[None, None, None, :]
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    if out_scale is not None:
        return requantize(y, out_scale, out_qmax)
    return y.astype(out_dtype)


def lowrank_conv_ref(x_q, u_q, v_q, su, sv, bu, bv, *, sx, h_scale, stride=1,
                     relu=False, out_scale=None, h_qmax=127.0,
                     out_qmax=127.0):
    """Chained two-conv oracle for kernels/lowrank_conv.lowrank_conv: the
    u conv requantizes its output to int8 on the static ``h_scale`` grid
    (exactly what the fused kernel does to its VMEM intermediate), then the
    1x1 v conv applies the ordinary dequant(+bias)(+ReLU)(+requantize)
    epilogue."""
    v_q = v_q.reshape(1, 1, v_q.shape[-2], v_q.shape[-1])
    h_q = quant_conv_ref(x_q, u_q, sx, su, bu, stride=stride,
                         out_scale=h_scale, out_qmax=h_qmax)
    return quant_conv_ref(h_q, v_q, h_scale, sv, bv, relu=relu,
                          out_scale=out_scale, out_qmax=out_qmax)


def decode_attention_ref(q, k, v, valid):
    """q: (B,H,D); k,v: (B,S,K,D); valid: (B,S) bool. GQA decode oracle."""
    B, H, D = q.shape
    K = k.shape[2]
    g = H // K
    qg = q.reshape(B, K, g, D) * (D ** -0.5)
    logits = jnp.einsum('bkgd,bskd->bkgs', qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum('bkgs,bskd->bkgd', p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
