"""Fused per-channel fake-quantization Pallas kernels (the QAT hot op).

QAT evaluates quantize→dequantize on every weight every step.  XLA's naive
lowering materializes abs/max/round intermediates in HBM; here there are two
strategies:

* :func:`fake_quant` — two VMEM-tiled kernels (reduction kernel accumulates
  per-column amax across K tiles; quantize kernel is a single elementwise
  sweep with the (bn,)-scales block resident in VMEM).  W streams through
  HBM twice (amax read + quantize read/write).
* :func:`fake_quant_fused` — single-pass variant: each grid step holds a
  full (K, bn) column stripe in VMEM, computes the per-column amax and
  quantizes in one sweep, so W is read from HBM exactly once.  Use it when
  the stripe fits VMEM (K * bn * 4B ≲ a few MB — true for every weight in
  this repo); fall back to the two-kernel version for huge K.

Awkward dims are zero-padded to the next 128 multiple and sliced back
(zero rows never win the abs-max; see kernels/tiling.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import fit_or_pad


def _amax_kernel(w_ref, o_ref, *, n_k):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = jnp.maximum(o_ref[...],
                             jnp.max(jnp.abs(w_ref[...]), axis=0))


def _quant_kernel(w_ref, amax_ref, o_ref, *, qmax):
    scale = jnp.maximum(amax_ref[...], 1e-8) / qmax
    w = w_ref[...] / scale[None, :]
    o_ref[...] = (jnp.clip(jnp.round(w), -qmax - 1, qmax)
                  * scale[None, :]).astype(o_ref.dtype)


def _fused_kernel(w_ref, o_ref, *, qmax):
    w = w_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale[None, :]), -qmax - 1, qmax)
    o_ref[...] = (q * scale[None, :]).astype(o_ref.dtype)


def _pad2(w, K, N, Kp, Np):
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    return w


@functools.partial(jax.jit, static_argnames=('bits', 'bk', 'bn', 'interpret'))
def fake_quant(w, *, bits=8, bk=512, bn=256, interpret=False):
    """Per-output-channel (last-dim) symmetric fake quant of w (K, N)."""
    K, N = w.shape
    (bk, Kp), (bn, Np) = fit_or_pad(bk, K), fit_or_pad(bn, N)
    w = _pad2(w, K, N, Kp, Np)
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = pl.pallas_call(
        functools.partial(_amax_kernel, n_k=Kp // bk),
        grid=(Np // bn, Kp // bk),
        in_specs=[pl.BlockSpec((bk, bn), lambda j, k: (k, j))],
        out_specs=pl.BlockSpec((bn,), lambda j, k: (j,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(Kp // bk, Np // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bn,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Kp, Np), w.dtype),
        interpret=interpret,
    )(w, amax)
    return out[:K, :N] if (Kp, Np) != (K, N) else out


@functools.partial(jax.jit, static_argnames=('bits', 'bn', 'interpret'))
def fake_quant_fused(w, *, bits=8, bn=256, interpret=False):
    """Single-pass fake quant: one HBM read of W instead of two.

    Holds a full (K, bn) column stripe in VMEM per grid step, so the amax
    reduction and the rounding sweep fuse into one kernel.
    """
    K, N = w.shape
    bn, Np = fit_or_pad(bn, N)
    w = _pad2(w, K, N, K, Np)
    qmax = 2.0 ** (bits - 1) - 1.0
    out = pl.pallas_call(
        functools.partial(_fused_kernel, qmax=qmax),
        grid=(Np // bn,),
        in_specs=[pl.BlockSpec((K, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((K, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((K, Np), w.dtype),
        interpret=interpret,
    )(w)
    return out[:, :N] if Np != N else out
