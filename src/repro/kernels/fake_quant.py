"""Fused per-channel fake-quantization Pallas kernels (the QAT hot op).

QAT evaluates quantize→dequantize on every weight every step.  XLA's naive
lowering materializes abs/max/round intermediates in HBM; here the abs-max
reduction and the rounding pass are two VMEM-tiled kernels (reduction
kernel accumulates per-column amax across K tiles; quantize kernel is a
single elementwise sweep with the (bn,)-scales block resident in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fit(block: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= ``block`` (prefers mult. of 128)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _amax_kernel(w_ref, o_ref, *, n_k):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = jnp.maximum(o_ref[...],
                             jnp.max(jnp.abs(w_ref[...]), axis=0))


def _quant_kernel(w_ref, amax_ref, o_ref, *, qmax):
    scale = jnp.maximum(amax_ref[...], 1e-8) / qmax
    w = w_ref[...] / scale[None, :]
    o_ref[...] = (jnp.clip(jnp.round(w), -qmax - 1, qmax)
                  * scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bits', 'bk', 'bn', 'interpret'))
def fake_quant(w, *, bits=8, bk=512, bn=256, interpret=False):
    """Per-output-channel (last-dim) symmetric fake quant of w (K, N)."""
    K, N = w.shape
    bk, bn = _fit(bk, K), _fit(bn, N)
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = pl.pallas_call(
        functools.partial(_amax_kernel, n_k=K // bk),
        grid=(N // bn, K // bk),
        in_specs=[pl.BlockSpec((bk, bn), lambda j, k: (k, j))],
        out_specs=pl.BlockSpec((bn,), lambda j, k: (j,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(K // bk, N // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bn,), lambda i, j: (j,))],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), w.dtype),
        interpret=interpret,
    )(w, amax)
