"""Pallas TPU kernels for the compression chain — which kernel serves which
pass (D→P→L→Q→E):

====================  =====================================================
Pass / phase          Kernel
====================  =====================================================
Q at inference        ``quant_matmul.py`` — W8A8 int8 MXU matmul, fused
                      dequant(+bias+ReLU) epilogue; with ``out_scale`` the
                      epilogue *requantizes* (int32 acc → static scale →
                      int8 out), the primitive behind int8-resident serving
Q at inference        ``quant_conv.py`` — NHWC conv lowered to int8 matmul
                      tiles via im2col K-axis accumulation (conv layers);
                      im2col gather indices are lru-cached per geometry
Q at inference        ``depthwise_conv.py`` — direct (non-im2col) grouped/
                      depthwise conv: per-channel int8 VPU MACs over the
                      spatial window, shared requantize epilogue, bit-exact
                      vs the lax.conv oracle (kills the fp32 fallback)
L∘Q at inference      ``lowrank_conv.py`` — a factored (u, v) conv pair in
                      ONE launch: the rank-r intermediate lives in VMEM
                      scratch (lane-padded when r < 128), requantized on a
                      static grid, bit-exact with the chained pair; COUT is
                      a grid axis, so any width fits; ``lowering_costs``
                      prices fused vs chained for export-time selection
Q during QAT          ``fake_quant.py`` — per-channel quantize→dequantize;
                      two-kernel amax→quantize, or ``fake_quant_fused``
                      (single HBM pass)
E at decode           ``decode_attention.py`` — flash-decode (+int8-KV
                      variant) behind the early-exit serving loop
====================  =====================================================

Int8-resident dataflow (core/export.py ``calibrate=...``): weight scales
are static from export (PR 1); activation scales are static from a
calibration batch, so no abs-max pass reads any activation at serve time.
Kernel boundaries carry int8 — the requantize epilogue writes int8 to HBM
and the next kernel consumes it with the producer's scale; fp32 appears
only at the logit heads (depthwise layers run the int8 kernel, so no conv
falls back to fp32).  Factored layers inside the fused envelope
(``lowrank_conv.fits_fused``: rank within one 128 lane tile) are priced
fused-vs-chained per layer at export (``lowrank_conv.lowering_costs`` or
wall-clock measurement); wider ranks always chain two launches.

``ops.py`` holds the jit'd public wrappers (interpret-mode on CPU, oracle
fallbacks); ``ref.py`` the pure-jnp oracles every kernel is tested against;
``tiling.py`` the shared block-fitting/padding policy.  The export pass in
core/export.py is what routes a compressed model onto these kernels.
"""
