"""Pallas TPU kernels for the compression chain — which kernel serves which
pass (D→P→Q→E):

====================  =====================================================
Pass / phase          Kernel
====================  =====================================================
Q at inference        ``quant_matmul.py`` — W8A8 int8 MXU matmul, fused
                      dequant(+bias+ReLU) epilogue (fc / exit heads)
Q at inference        ``quant_conv.py`` — NHWC conv lowered to int8 matmul
                      tiles via im2col K-axis accumulation (conv layers)
Q during QAT          ``fake_quant.py`` — per-channel quantize→dequantize;
                      two-kernel amax→quantize, or ``fake_quant_fused``
                      (single HBM pass)
E at decode           ``decode_attention.py`` — flash-decode (+int8-KV
                      variant) behind the early-exit serving loop
====================  =====================================================

``ops.py`` holds the jit'd public wrappers (interpret-mode on CPU, oracle
fallbacks); ``ref.py`` the pure-jnp oracles every kernel is tested against;
``tiling.py`` the shared block-fitting/padding policy.  The export pass in
core/export.py is what routes a compressed model onto these kernels.
"""
