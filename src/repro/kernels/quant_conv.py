"""Int8 NHWC conv lowered to MXU matmul tiles — the serving realization of
the paper's Q pass for conv layers.

Lowering: SAME-padded im2col turns the conv into
``patches (B*OH*OW, KH*KW*CIN) @ w (KH*KW*CIN, COUT)`` — the patch axis
becomes the matmul K axis, accumulated tile-by-tile in the int32 VMEM
scratch of the shared quant_matmul kernel (kernels/quant_matmul.py), with
the dequant + bias + ReLU (or requantize — see ``out_scale``) epilogue
fused into the final K step.  Patch extraction itself is a pure
memory-layout op: one int8 gather over the padded spatial plane, with the
index computation cached per geometry (``_im2col_plan``) so it never
re-runs across calls or traces; all the FLOPs run on the Pallas kernel.

Because quantization is symmetric (zero-point 0), the SAME zero-padding is
value-exact in the quantized domain: padded int8 zeros contribute nothing
to the int32 accumulator.

Grouped convs (MobileNet depthwise — ~21% of its MACs, nothing like
negligible) are block-diagonal in im2col form: int8 matmul tiles would be
~CIN x wasted, so the ops-layer wrapper (kernels/ops.py) serves them on
the direct per-channel kernel in kernels/depthwise_conv.py instead of
this one — int8 VPU MACs, no patch materialization, no fp32 fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant_matmul import quant_matmul


def conv_out_hw(h: int, w: int, stride: int) -> tuple[int, int]:
    """SAME-padding output spatial dims."""
    return -(-h // stride), -(-w // stride)


@functools.lru_cache(maxsize=None)
def _im2col_plan(h: int, w: int, kh: int, kw: int, stride: int):
    """Cached im2col geometry: SAME pads plus the flat gather indices.

    Returns (pads, (oh, ow), idx) where ``idx`` is an int32 numpy array of
    shape (OH*OW*KH*KW,) indexing the *padded* HP*WP spatial plane in
    (oh, ow)-major, (kh, kw)-minor order.  The index computation is pure
    Python/numpy on static shapes — the lru_cache means it runs once per
    layer geometry for the life of the process, not once per call/trace
    (the old shift+concat built kh*kw strided slices at every trace).
    """
    oh, ow = conv_out_hw(h, w, stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - w, 0)
    hp, wp = h + pad_h, w + pad_w
    rows = (np.arange(oh)[:, None] * stride + np.arange(kh)[None, :])
    cols = (np.arange(ow)[:, None] * stride + np.arange(kw)[None, :])
    # (oh, ow, kh, kw) -> flat index into the padded plane
    idx = (rows[:, None, :, None] * wp + cols[None, :, None, :])
    return ((pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2)), (oh, ow), \
        idx.reshape(-1).astype(np.int32)


def im2col_nhwc(x, kh: int, kw: int, stride: int = 1):
    """SAME im2col: x (B,H,W,C) -> patches (B*OH*OW, KH*KW*C), plus (OH,OW).

    The flattened patch axis is (kh, kw, C)-major — exactly the order of
    ``w.reshape(KH*KW*C, COUT)`` for HWIO weights.  Works on any dtype; the
    int8 serving path feeds already-quantized activations so the zero pad
    is exact.  Lowered as one gather over the padded spatial plane with
    cached (per-geometry) indices — a pure memory-layout op.
    """
    B, H, W, C = x.shape
    (ph, pw), (oh, ow), idx = _im2col_plan(H, W, kh, kw, stride)
    x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    flat = x.reshape(B, x.shape[1] * x.shape[2], C)
    patches = jnp.take(flat, jnp.asarray(idx), axis=1)
    return patches.reshape(B * oh * ow, kh * kw * C), (oh, ow)


@functools.partial(jax.jit, static_argnames=('stride', 'relu', 'bm', 'bn',
                                             'bk', 'out_dtype', 'interpret',
                                             'out_scale', 'out_qmax'))
def quant_conv(x_q, w_q, sx, sw, bias=None, *, stride=1, relu=False,
               bm=128, bn=128, bk=256, out_dtype=jnp.float32,
               interpret=False, out_scale=None, out_qmax=127.0):
    """Int8 NHWC conv with fused dequant + bias + ReLU epilogue.

    x_q: int8 (B,H,W,CIN); w_q: int8 (KH,KW,CIN,COUT); sx: scalar fp32
    per-tensor activation scale; sw: (COUT,) fp32 static per-channel weight
    scales; bias: (COUT,) fp32 or None.  Returns (B,OH,OW,COUT) out_dtype.

    ``out_scale`` (static float) selects the requantize epilogue of
    kernels/quant_matmul.py: the output is int8 at that scale, so the
    activation never round-trips through fp32 HBM between layers.
    """
    B, H, W, C = x_q.shape
    kh, kw, c2, n = w_q.shape
    assert C == c2, (C, c2)
    patches, (oh, ow) = im2col_nhwc(x_q, kh, kw, stride)
    m = B * oh * ow
    out = quant_matmul(patches, w_q.reshape(kh * kw * C, n),
                       jnp.full((m,), sx, jnp.float32),
                       sw.astype(jnp.float32), bias,
                       bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, relu=relu,
                       interpret=interpret, out_scale=out_scale,
                       out_qmax=out_qmax)
    return out.reshape(B, oh, ow, n)
