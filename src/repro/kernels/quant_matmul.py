"""W8A8 quantized matmul Pallas kernel — the TPU realization of the paper's
Q pass at inference time.

The GPU papers realize low-bit wins with bit-serial/CUDA-core tricks; on TPU
the win comes from feeding the 128x128 MXU int8 operands (2x MACs/cycle vs
bf16 on v5e) and halving HBM traffic.  Tiling: (bm x bk) @ (bk x bn) blocks
resident in VMEM, fp32 dequant fused into the epilogue with per-row
activation scales and per-column weight scales (also VMEM-resident).

Grid is (M/bm, N/bn, K/bk) with the K axis innermost: the int32 accumulator
lives in a VMEM scratch and is rescaled+flushed once per (m, n) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fit(block: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= ``block`` (prefers mult. of 128)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _done():
        scale = sx_ref[...][:, None] * sw_ref[...][None, :]
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bm', 'bn', 'bk', 'out_dtype',
                                             'interpret'))
def quant_matmul(x_q, w_q, sx, sw, *, bm=128, bn=128, bk=256,
                 out_dtype=jnp.float32, interpret=False):
    """x_q: int8 (M,K); w_q: int8 (K,N); sx: (M,) fp32; sw: (N,) fp32."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    bm, bn, bk = _fit(bm, M), _fit(bn, N), _fit(bk, K)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, sx, sw)
