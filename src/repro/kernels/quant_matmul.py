"""W8A8 quantized matmul Pallas kernel — the TPU realization of the paper's
Q pass at inference time.

The GPU papers realize low-bit wins with bit-serial/CUDA-core tricks; on TPU
the win comes from feeding the 128x128 MXU int8 operands (2x MACs/cycle vs
bf16 on v5e) and halving HBM traffic.  Tiling: (bm x bk) @ (bk x bn) blocks
resident in VMEM, fp32 dequant fused into the epilogue with per-row
activation scales and per-column weight scales (also VMEM-resident).

Grid is (M/bm, N/bn, K/bk) with the K axis innermost: the int32 accumulator
lives in a VMEM scratch and is rescaled+flushed once per (m, n) tile.  The
epilogue can optionally fuse a per-column bias add and ReLU — this is what
the exported serving path (core/export.py) uses for conv layers, where the
matmul K axis is the im2col patch axis.

Awkward dims (primes, non-128 multiples with no decent divisor) are
zero-padded to the next 128 multiple and sliced back — zero int8 rows/cols
contribute nothing to the int32 accumulator, so padding is value-exact.

``out_scale`` turns the epilogue into a **requantize** epilogue: after the
fused dequant(+bias)(+ReLU) the result is divided by a *static* output
scale, rounded, clipped to ``out_qmax`` and written as int8 — the int8-
resident serving path (core/export.py) uses this so activations stay int8
in HBM between layers; the next kernel consumes them with the same static
scale, so no per-call abs-max pass ever touches the activation tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import fit_or_pad


def _qmm_kernel(*refs, n_k, relu, has_bias, out_scale, out_qmax):
    if has_bias:
        x_ref, w_ref, sx_ref, sw_ref, b_ref, o_ref, acc_ref = refs
    else:
        (x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref), b_ref = refs, None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _done():
        scale = sx_ref[...][:, None] * sw_ref[...][None, :]
        y = acc_ref[...].astype(jnp.float32) * scale
        if b_ref is not None:
            y = y + b_ref[...][None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        if out_scale is not None:   # requantize epilogue: int8 stays in HBM
            y = jnp.clip(jnp.round(y / out_scale), -out_qmax - 1.0, out_qmax)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('bm', 'bn', 'bk', 'out_dtype',
                                             'relu', 'interpret', 'out_scale',
                                             'out_qmax'))
def quant_matmul(x_q, w_q, sx, sw, bias=None, *, bm=128, bn=128, bk=256,
                 out_dtype=jnp.float32, relu=False, interpret=False,
                 out_scale=None, out_qmax=127.0):
    """x_q: int8 (M,K); w_q: int8 (K,N); sx: (M,) fp32; sw: (N,) fp32.

    Optional fused epilogue: ``bias`` (N,) fp32 added after dequant, then
    ReLU when ``relu=True``.  Returns (M, N) ``out_dtype``.

    ``out_scale`` (static Python float) switches the epilogue to requantize:
    the fp32 result is divided by it, rounded and clipped to ``out_qmax``,
    and the output is int8 (``out_dtype`` is ignored) — the next layer
    consumes it directly with the same static scale.
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    if out_scale is not None:
        out_scale, out_dtype = float(out_scale), jnp.int8
    (bm, Mp), (bn, Np), (bk, Kp) = (fit_or_pad(bm, M), fit_or_pad(bn, N),
                                    fit_or_pad(bk, K))
    if (Mp, Np, Kp) != (M, N, K):
        x_q = jnp.pad(x_q, ((0, Mp - M), (0, Kp - K)))
        w_q = jnp.pad(w_q, ((0, Kp - K), (0, Np - N)))
        sx = jnp.pad(sx, (0, Mp - M))
        sw = jnp.pad(sw, (0, Np - N))
        if bias is not None:
            bias = jnp.pad(bias, (0, Np - N))
    n_k = Kp // bk
    grid = (Mp // bm, Np // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((bm,), lambda i, j, k: (i,)),
        pl.BlockSpec((bn,), lambda i, j, k: (j,)),
    ]
    args = [x_q, w_q, sx, sw]
    if bias is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(bias.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k, relu=relu,
                          has_bias=bias is not None,
                          out_scale=out_scale, out_qmax=float(out_qmax)),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*args)
    return out[:M, :N] if (Mp, Np) != (M, N) else out
