"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode, which
executes the kernel body in Python for correctness validation; on TPU the
same BlockSpecs compile to Mosaic.  ``use_pallas=False`` falls back to the
pure-jnp oracle (used by models at training time on CPU, where interpret
mode is too slow to train through).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pallas_decode
from repro.kernels.fake_quant import fake_quant as _pallas_fake_quant
from repro.kernels.quant_matmul import quant_matmul as _pallas_qmm


def _interpret() -> bool:
    return jax.default_backend() == 'cpu'


def quant_matmul(x_q, w_q, sx, sw, *, use_pallas=True, **kw):
    if not use_pallas:
        return ref.quant_matmul_ref(x_q, w_q, sx, sw)
    return _pallas_qmm(x_q, w_q, sx, sw, interpret=_interpret(), **kw)


def fake_quant(w, bits=8, *, use_pallas=True, **kw):
    if not use_pallas:
        return ref.fake_quant_ref(w, bits)
    return _pallas_fake_quant(w, bits=bits, interpret=_interpret(), **kw)


def decode_attention(q, k, v, valid, *, use_pallas=True, **kw):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v,
                                        jnp.broadcast_to(valid,
                                                         (q.shape[0],
                                                          k.shape[1])))
    return _pallas_decode(q, k, v, valid, interpret=_interpret(), **kw)


def quantize_dense_int8(x, w):
    """Dynamic-quantize x and w to int8 and run the quantized matmul.

    The int8 *serving* path for a dense layer: per-row activation scales,
    per-column weight scales.  Returns fp32 (M, N).
    """
    sx = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / sx[:, None]), -128, 127).astype(jnp.int8)
    sw = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / 127.0
    wq = jnp.clip(jnp.round(w / sw[None, :]), -128, 127).astype(jnp.int8)
    return quant_matmul(xq, wq, sx, sw)
