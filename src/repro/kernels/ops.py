"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode, which
executes the kernel body in Python for correctness validation; on TPU the
same BlockSpecs compile to Mosaic.  ``use_pallas=False`` falls back to the
pure-jnp oracle (used by models at training time on CPU, where interpret
mode is too slow to train through).

Serving entry points (consumed by core/export.py):

* :func:`prequantize_weight` — per-out-channel weight int8 quantization,
  run ONCE at export; the returned (w_q, sw) are static at serve time.
* :func:`quant_dense` / :func:`quant_conv_nhwc` — dynamic activation
  quantization + the int8 Pallas matmul/conv kernels with fused epilogue
  (the PR-1 exported path: one abs-max pass per layer, fp32 between
  layers).
* :func:`quant_conv_static` / :func:`quant_dense_static` /
  :func:`depthwise_conv_static` / :func:`lowrank_conv_nhwc` — the
  int8-resident path: activations arrive already int8 on a *static* scale
  captured at export calibration, and the requantize epilogue
  (``out_scale``) keeps them int8 on the way out.
  ``depthwise_conv_static`` serves grouped/depthwise convs on the direct
  per-channel kernel (kernels/depthwise_conv.py) — there is no fp32
  fallback left on the resident path.  ``lowrank_conv_nhwc`` serves a
  factored (u, v) conv pair as ONE Pallas launch
  (kernels/lowrank_conv.py); its jnp fallback chains the two convs with
  identical requantize math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pallas_decode
from repro.kernels.depthwise_conv import depthwise_conv as _pallas_dw_conv
from repro.kernels.depthwise_conv import fits_depthwise
from repro.kernels.fake_quant import fake_quant as _pallas_fake_quant
from repro.kernels.fake_quant import fake_quant_fused as _pallas_fq_fused
from repro.kernels.lowrank_conv import lowrank_conv as _pallas_lr_conv
from repro.kernels.quant_conv import quant_conv as _pallas_qconv
from repro.kernels.quant_matmul import quant_matmul as _pallas_qmm


def _interpret() -> bool:
    return jax.default_backend() == 'cpu'


def quant_matmul(x_q, w_q, sx, sw, bias=None, *, use_pallas=True, relu=False,
                 **kw):
    if not use_pallas:
        y = ref.quant_matmul_ref(x_q, w_q, sx, sw)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return jnp.maximum(y, 0.0) if relu else y
    return _pallas_qmm(x_q, w_q, sx, sw, bias, relu=relu,
                       interpret=_interpret(), **kw)


def fake_quant(w, bits=8, *, use_pallas=True, fused=None, **kw):
    """Fake-quantize w; ``fused`` selects the single-HBM-pass kernel.

    ``fused=None`` (auto) picks it whenever the (K, bn) column stripe fits
    a conservative VMEM budget — true for every weight in this repo — and
    falls back to the two-kernel amax→quantize path for huge K.
    """
    if not use_pallas:
        return ref.fake_quant_ref(w, bits)
    if fused is None:
        from repro.kernels.tiling import VMEM_BUDGET
        bn = kw.get('bn', 256)
        fused = w.shape[0] * min(bn, w.shape[1]) * 4 <= VMEM_BUDGET // 2
    if fused:
        kw.pop('bk', None)
        return _pallas_fq_fused(w, bits=bits, interpret=_interpret(), **kw)
    return _pallas_fake_quant(w, bits=bits, interpret=_interpret(), **kw)


def decode_attention(q, k, v, valid, *, use_pallas=True, **kw):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v,
                                        jnp.broadcast_to(valid,
                                                         (q.shape[0],
                                                          k.shape[1])))
    return _pallas_decode(q, k, v, valid, interpret=_interpret(), **kw)


# --------------------------------------------------------- int8 serving path


def _act_qmax(a_bits: int) -> float:
    return 2.0 ** (a_bits - 1) - 1.0


def prequantize_weight(w, *, bits: int = 8):
    """Per-out-channel (last dim) symmetric int8 weight quantization.

    Run once at export time — the serving kernels consume (w_q, sw) as
    static operands and never recompute the weight abs-max.  Works on any
    rank: the reduction covers every axis but the last.  Routes through
    core.quantization.quantize_weight (the single weight quantizer, incl.
    the bits=1 DoReFa branch).  Returns (w_q int8, sw (out,) fp32).
    """
    from repro.core.quantization import quantize_weight
    w_q, scale = quantize_weight(w.astype(jnp.float32), bits, axis=-1)
    return w_q.astype(jnp.int8), scale.reshape(-1).astype(jnp.float32)


def quantize_act(x, *, a_bits: int = 8, per_row: bool = False):
    """Dynamic activation quantization (the only per-call scale compute).

    per_row=True gives each row of a 2D x its own scale; otherwise one
    per-tensor scale (matching core.quantization.fake_quant_act's QAT
    clip, so serving stays on the QAT grid).  Returns (x_q int8, sx).
    """
    qmax = _act_qmax(a_bits)
    if per_row:
        s = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-8) / qmax
        xq = jnp.clip(jnp.round(x / s[:, None]), -qmax - 1, qmax)
    else:
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
        xq = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
    return xq.astype(jnp.int8), s.astype(jnp.float32)


def quant_dense(x, w_q, sw, *, a_bits=8, per_row=True, use_pallas=True, **kw):
    """Int8 dense with prequantized weights: x fp32 (M,K) @ w_q int8 (K,N).

    Activations are dynamically quantized (per-row or per-tensor scale);
    weight scales sw (N,) are static.  Returns fp32 (M, N).
    """
    xq, sx = quantize_act(x, a_bits=a_bits, per_row=per_row)
    if not per_row:
        sx = jnp.full((x.shape[0],), sx, jnp.float32)
    return quant_matmul(xq, w_q, sx, sw.reshape(-1), use_pallas=use_pallas,
                        **kw)


def quantize_dense_int8(x, w, **kw):
    """Dynamic-quantize x and w to int8 and run the quantized matmul.

    Thin wrapper over prequantize_weight + quant_dense, kept for callers
    that hold fp32 weights; the serving path prequantizes once at export
    and calls quant_dense directly.
    """
    w_q, sw = prequantize_weight(w)
    return quant_dense(x, w_q, sw, **kw)


def quant_conv_nhwc(x, w_q, sw, bias=None, *, stride=1, groups=1, relu=False,
                    a_bits=8, use_pallas=True, **kw):
    """Int8 NHWC conv with prequantized weights and fused epilogue.

    x fp32 (B,H,W,CIN); w_q int8 (KH,KW,CIN,COUT); sw (COUT,) static.
    Activations get one dynamic per-tensor scale (the QAT grid).  Grouped
    convs with per-group depth 1 (depthwise, any channel multiplier) serve
    on the direct per-channel kernel (kernels/depthwise_conv.py) — im2col
    would waste ~CIN x of MXU tiles on their block-diagonal structure.
    Only per-group depth > 1 (absent from this repo's families) still
    dequantizes through lax.conv.
    """
    xq, sx = quantize_act(x, a_bits=a_bits)
    if groups > 1:
        if use_pallas and fits_depthwise(w_q.shape):
            return _pallas_dw_conv(xq, w_q, sx, sw, bias, stride=stride,
                                   relu=relu, interpret=_interpret())
        return ref.quant_conv_ref(xq, w_q, sx, sw, bias, stride=stride,
                                  relu=relu, groups=groups)
    if not use_pallas:
        return ref.quant_conv_ref(xq, w_q, sx, sw, bias, stride=stride,
                                  relu=relu)
    return _pallas_qconv(xq, w_q, sx, sw, bias, stride=stride, relu=relu,
                         interpret=_interpret(), **kw)


# ------------------------------------------- int8-resident serving entries


def quant_conv_static(x_q, w_q, sw, bias=None, *, sx, stride=1, relu=False,
                      out_scale=None, out_qmax=127.0, use_pallas=True, **kw):
    """Int8 conv on an *already-quantized* activation with a static scale.

    x_q int8 (B,H,W,CIN) on the static per-tensor grid ``sx`` (a Python
    float from export calibration); no abs-max pass runs.  With
    ``out_scale`` the output is int8 on that static grid — the layer is
    int8-in/int8-out in HBM.
    """
    if not use_pallas:
        return ref.quant_conv_ref(x_q, w_q, sx, sw, bias, stride=stride,
                                  relu=relu, out_scale=out_scale,
                                  out_qmax=out_qmax)
    return _pallas_qconv(x_q, w_q, sx, sw, bias, stride=stride, relu=relu,
                         out_scale=out_scale, out_qmax=out_qmax,
                         interpret=_interpret(), **kw)


def depthwise_conv_static(x_q, w_q, sw, bias=None, *, sx, stride=1,
                          relu=False, out_scale=None, out_qmax=127.0,
                          use_pallas=True, **kw):
    """Int8 depthwise/grouped conv on a statically-quantized activation.

    The resident-path twin of :func:`quant_conv_static` for grouped convs
    with per-group input depth 1: x_q int8 (B,H,W,CIN) on the static grid
    ``sx``; w_q int8 (KH,KW,1,COUT) with COUT a multiple of CIN.  Serves on
    the direct per-channel Pallas kernel — int8 MACs, shared requantize
    epilogue, bit-exact vs ref.depthwise_conv_ref — so MobileNet's
    depthwise layers are int8-in/int8-out like every other resident layer
    (the old fp32 lax.conv fallback is gone).
    """
    if not use_pallas:
        return ref.depthwise_conv_ref(x_q, w_q, sx, sw, bias, stride=stride,
                                      relu=relu, out_scale=out_scale,
                                      out_qmax=out_qmax)
    return _pallas_dw_conv(x_q, w_q, sx, sw, bias, stride=stride, relu=relu,
                           out_scale=out_scale, out_qmax=out_qmax,
                           interpret=_interpret(), **kw)


def quant_dense_static(x_q, w_q, sw, bias=None, *, sx, relu=False,
                       out_scale=None, out_qmax=127.0, use_pallas=True, **kw):
    """Int8 dense on a statically-quantized activation (cf.
    :func:`quant_conv_static`).  x_q int8 (M,K); returns fp32 (M,N), or
    int8 when ``out_scale`` is set."""
    if not use_pallas:
        y = ref.quant_matmul_ref(x_q, w_q,
                                 jnp.full((x_q.shape[0],), sx, jnp.float32),
                                 sw.reshape(-1))
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        if out_scale is not None:
            return ref.requantize(y, out_scale, out_qmax)
        return y
    return _pallas_qmm(x_q, w_q, jnp.full((x_q.shape[0],), sx, jnp.float32),
                       sw.reshape(-1), bias, relu=relu, out_scale=out_scale,
                       out_qmax=out_qmax, interpret=_interpret(), **kw)


def lowrank_conv_nhwc(x_q, u_q, v_q, su, sv, bu, bv, *, sx, h_scale,
                      stride=1, relu=False, out_scale=None, h_qmax=127.0,
                      out_qmax=127.0, use_pallas=True, **kw):
    """Serve a factored (u, v) conv pair — ONE Pallas launch on the kernel
    path (kernels/lowrank_conv.py: the rank intermediate never leaves
    VMEM), or the chained jnp reference with identical requantize math."""
    if not use_pallas:
        return ref.lowrank_conv_ref(x_q, u_q, v_q, su, sv, bu, bv, sx=sx,
                                    h_scale=h_scale, stride=stride,
                                    relu=relu, out_scale=out_scale,
                                    h_qmax=h_qmax, out_qmax=out_qmax)
    return _pallas_lr_conv(x_q, u_q, v_q, su, sv, bu, bv, sx=float(sx),
                           h_scale=float(h_scale), stride=stride, relu=relu,
                           out_scale=out_scale, h_qmax=h_qmax,
                           out_qmax=out_qmax, interpret=_interpret(), **kw)
