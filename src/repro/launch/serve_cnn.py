"""CNN serving launcher: export a compressed CNN and serve batched traffic.

Runs the short chain (or skips straight to export with --no-train), compiles
the result to the int8 serving path (core/export.py), and drives a batched
early-exit serving loop over a synthetic eval stream, reporting throughput
and the per-stage exit distribution — the deployed realization of the
paper's D→P→Q→E chain.

    PYTHONPATH=src python -m repro.launch.serve_cnn --config resnet8-cifar \
        --batches 8 --batch 64 --threshold 0.85

``--server`` switches from caller-assembled static batches to the request
runtime (repro/serving/): requests arrive on a Poisson trace, the
continuous-batching scheduler forms tile-padded batches, returns
early-exited samples after their stage segment, compacts the survivors,
and backfills freed slots from the queue; the run reports p50/p99 latency,
throughput, exit mix, and batch occupancy.

    PYTHONPATH=src python -m repro.launch.serve_cnn --server \
        --requests 256 --rate 800 --slots 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _serve_trace(model, fam, cfg, args):
    """--server mode: drive the request scheduler over a Poisson trace on
    the wall clock (cf. benchmarks/serving_load.py for the median-cost
    simulated A/B against static batching)."""
    from repro.core.export import calibrate_exit_threshold
    from repro.serving import ContinuousBatchScheduler, Request

    rng = np.random.default_rng(0)
    stream = fam.eval_batches(-(-args.requests // args.batch), args.batch)
    xs = jnp.concatenate([x for x, _ in stream])[:args.requests]
    ys = jnp.concatenate([y for x, y in stream])[:args.requests]
    threshold = args.threshold
    if threshold is None:
        threshold = calibrate_exit_threshold(model, xs[:args.slots])
        print(f'calibrated exit threshold: {threshold:.4f}')
    t = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    reqs = [Request(i, xs[i], float(t[i])) for i in range(args.requests)]
    sched = ContinuousBatchScheduler(
        model, slots=args.slots, threshold=threshold,
        max_wait=args.max_wait)
    # warm EVERY stage program off the clock: threshold 2.0 means nothing
    # exits, so the warm batch traverses all segments (a real-threshold
    # warm-up could exit at head 1 and leave deeper segments uncompiled,
    # charging their jit to the first unlucky real batch's latency)
    ContinuousBatchScheduler(
        model, slots=args.slots, threshold=2.0).run_trace(
            [Request(-1 - i, xs[i], 0.0)
             for i in range(min(4, args.requests))])
    completions, metrics = sched.run_trace(reqs)
    s = metrics.summary()
    hit = sum(1 for i in range(args.requests)
              if completions[i].pred == int(ys[i]))
    print(f'config={cfg.name} backend={jax.default_backend()} '
          f'slots={sched.slots} threshold={threshold:.3f}')
    print(f"served {s['n_requests']} requests at rate={args.rate:.0f}/s: "
          f"throughput={s['throughput_rps']:.0f} req/s "
          f"p50={s['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={s['p99_latency_s'] * 1e3:.2f}ms "
          f"acc={hit / max(args.requests, 1):.3f}")
    print(f"  exit mix: {s['exit_mix']}  "
          f"occupancy: {s['batch_occupancy']}")


def main():
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.core.passes import Trainer
    from repro.data import SyntheticImages

    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--batch', type=int, default=64)
    ap.add_argument('--batches', type=int, default=8)
    ap.add_argument('--threshold', type=float, default=None,
                    help='exit threshold (default 0.85; --server default '
                         'calibrates on the stream)')
    ap.add_argument('--steps', type=int, default=60,
                    help='QAT fine-tune steps before export (0 = raw init)')
    ap.add_argument('--pallas', action='store_true',
                    help='force Pallas kernels (interpret mode on CPU)')
    ap.add_argument('--resident', action='store_true',
                    help='int8-resident plan: calibrate static activation '
                         'scales on the first eval batch (core/export.py)')
    ap.add_argument('--verify', nargs='?', const='strict', default=None,
                    choices=('strict', 'warn'),
                    help='run the static analyzer (repro/analysis) over '
                         'the export before serving and print the report; '
                         'strict (default) aborts on any error finding. '
                         'Implies --resident (rules read the layer plan).')
    ap.add_argument('--server', action='store_true',
                    help='request-level serving: Poisson arrivals through '
                         'the continuous-batching scheduler '
                         '(repro/serving/); implies --resident, and '
                         '--threshold none recalibrates on the stream')
    ap.add_argument('--requests', type=int, default=256,
                    help='--server: trace length')
    ap.add_argument('--rate', type=float, default=500.0,
                    help='--server: Poisson arrival rate (req/s)')
    ap.add_argument('--slots', type=int, default=32,
                    help='--server: scheduler batch slots (tile-padded)')
    ap.add_argument('--max-wait', type=float, default=0.05,
                    help='--server: run a partial batch once its oldest '
                         'request has waited this long (seconds)')
    args = ap.parse_args()
    if args.server or args.verify:
        args.resident = True

    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config]
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params, cfg,
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    if args.steps:
        trainer = Trainer(batch=args.batch, steps=args.steps)
        params, _ = trainer.fit(fam, cfg, params)

    stream = fam.eval_batches(args.batches, args.batch)
    model = export_cnn(params, cfg, use_pallas=True if args.pallas else None,
                       calibrate=stream[0][0] if args.resident else None,
                       verify=args.verify)
    if args.verify:
        # strict mode raised inside export_cnn already; print the report
        # (incl. info findings and visible skips) either way
        print(model.analysis)
    if args.resident:
        s = model.summary()
        print(f'layer plan: {s["kernel_launches"]} kernel launches, '
              f'{s["n_fused_lowrank"]} fused low-rank, '
              f'{s["n_depthwise"]} depthwise, '
              f'fallback MACs {s["fallback_mac_fraction"]:.1%}')
    if args.server:
        return _serve_trace(model, fam, cfg, args)
    threshold = 0.85 if args.threshold is None else args.threshold
    # warm the jit caches off the clock
    model.serve_early_exit(stream[0][0], threshold=threshold)

    stages = {s: 0 for s in cfg.exit_stages}
    hit = tot = 0
    t0 = time.perf_counter()
    for x, y in stream:
        pred, stage = model.serve_early_exit(x, threshold=threshold)
        jax.block_until_ready(pred)
        hit += int(jnp.sum(pred == y))
        tot += int(y.size)
        for s in stages:
            stages[s] += int(np.sum(np.asarray(stage) == s))
    dt = time.perf_counter() - t0

    print(f'config={cfg.name} backend={jax.default_backend()} '
          f'int8_path={"pallas" if args.pallas else "auto"}')
    print(f'served {tot} images in {dt:.3f}s '
          f'({tot / dt:.0f} img/s), acc={hit / max(tot, 1):.3f}')
    for s in sorted(stages):
        print(f'  exit@stage{s}: {stages[s] / max(tot, 1):.1%}')
    print(f'  final head:   {1 - sum(stages.values()) / max(tot, 1):.1%}')


if __name__ == '__main__':
    main()
