"""CNN serving launcher: export a compressed CNN and serve batched traffic.

Runs the short chain (or skips straight to export with --no-train), compiles
the result to the int8 serving path (core/export.py), and drives a batched
early-exit serving loop over a synthetic eval stream, reporting throughput
and the per-stage exit distribution — the deployed realization of the
paper's D→P→Q→E chain.

    PYTHONPATH=src python -m repro.launch.serve_cnn --config resnet8-cifar \
        --batches 8 --batch 64 --threshold 0.85
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.core.passes import Trainer
    from repro.data import SyntheticImages

    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--batch', type=int, default=64)
    ap.add_argument('--batches', type=int, default=8)
    ap.add_argument('--threshold', type=float, default=0.85)
    ap.add_argument('--steps', type=int, default=60,
                    help='QAT fine-tune steps before export (0 = raw init)')
    ap.add_argument('--pallas', action='store_true',
                    help='force Pallas kernels (interpret mode on CPU)')
    ap.add_argument('--resident', action='store_true',
                    help='int8-resident plan: calibrate static activation '
                         'scales on the first eval batch (core/export.py)')
    args = ap.parse_args()

    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config]
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params, cfg,
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    if args.steps:
        trainer = Trainer(batch=args.batch, steps=args.steps)
        params, _ = trainer.fit(fam, cfg, params)

    stream = fam.eval_batches(args.batches, args.batch)
    model = export_cnn(params, cfg, use_pallas=True if args.pallas else None,
                       calibrate=stream[0][0] if args.resident else None)
    if args.resident:
        s = model.summary()
        print(f'layer plan: {s["kernel_launches"]} kernel launches, '
              f'{s["n_fused_lowrank"]} fused low-rank, '
              f'fallback MACs {s["fallback_mac_fraction"]:.1%}')
    # warm the jit caches off the clock
    model.serve_early_exit(stream[0][0], threshold=args.threshold)

    stages = {s: 0 for s in cfg.exit_stages}
    hit = tot = 0
    t0 = time.perf_counter()
    for x, y in stream:
        pred, stage = model.serve_early_exit(x, threshold=args.threshold)
        jax.block_until_ready(pred)
        hit += int(jnp.sum(pred == y))
        tot += int(y.size)
        for s in stages:
            stages[s] += int(np.sum(np.asarray(stage) == s))
    dt = time.perf_counter() - t0

    print(f'config={cfg.name} backend={jax.default_backend()} '
          f'int8_path={"pallas" if args.pallas else "auto"}')
    print(f'served {tot} images in {dt:.3f}s '
          f'({tot / dt:.0f} img/s), acc={hit / max(tot, 1):.3f}')
    for s in sorted(stages):
        print(f'  exit@stage{s}: {stages[s] / max(tot, 1):.1%}')
    print(f'  final head:   {1 - sum(stages.values()) / max(tot, 1):.1%}')


if __name__ == '__main__':
    main()
