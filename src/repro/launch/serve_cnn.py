"""CNN serving launcher: export a compressed CNN and serve batched traffic.

Runs the short chain (or skips straight to export with --no-train), compiles
the result to the int8 serving path (core/export.py), and drives a batched
early-exit serving loop over a synthetic eval stream, reporting throughput
and the per-stage exit distribution — the deployed realization of the
paper's D→P→Q→E chain.

    PYTHONPATH=src python -m repro.launch.serve_cnn --config resnet8-cifar \
        --batches 8 --batch 64 --threshold 0.85

``--server`` switches from caller-assembled static batches to the request
runtime (repro/serving/): requests arrive on a Poisson trace, the
continuous-batching scheduler forms tile-padded batches, returns
early-exited samples after their stage segment, compacts the survivors,
and backfills freed slots from the queue; the run reports p50/p99 latency,
throughput, exit mix, and batch occupancy.

    PYTHONPATH=src python -m repro.launch.serve_cnn --server \
        --requests 256 --rate 800 --slots 32

``--deadline-ms`` attaches per-request deadlines and turns on the SLO
layer (deadline admission + graceful degradation through the exit heads;
no admitted request finishes late).  ``--chaos`` serves the trace on the
replica pool under a seeded fault plan (replica kill mid-batch, straggler
slowdown) and reports availability/failover/straggler counters.  Both run
on a simulated clock built from locally measured stage costs.

    PYTHONPATH=src python -m repro.launch.serve_cnn --server \
        --requests 128 --deadline-ms 40 --chaos --replicas 2

``--pipeline`` serves the trace pipeline-parallel across every visible
jax device: the placement solver packs stage *k* onto a device by
measured cost (greedy LPT, the reported load-balance bound), the int8
carry streams between devices, and the run prints the placement next to
the usual latency numbers.  Force a device count with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the count is
locked at backend init).  ``--chaos`` composes: a seeded device kill
mid-trace, survivors re-solved.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve_cnn --server \
        --pipeline --requests 256 --rate 800 --slots 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _measure_stage_costs(model, x, iters=5):
    """Median per-segment batch cost (seconds) at the geometry of ``x`` —
    the simulated clock for --deadline-ms / --chaos runs."""
    costs, carry = [], x
    for k in range(model.n_stages):
        fn = model.stage_fns[k]
        jax.block_until_ready(fn(model.params, carry))   # compile off-clock
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(model.params, carry))
            ts.append(time.perf_counter() - t0)
        costs.append(float(np.median(ts)))
        if k < model.n_stages - 1:
            _, carry = model.run_stage(k, carry)
    return costs


def _serve_trace(model, fam, cfg, args, tracer=None):
    """--server mode: drive the request scheduler over a Poisson trace on
    the wall clock (cf. benchmarks/serving_load.py for the median-cost
    simulated A/B against static batching).  --deadline-ms adds the SLO
    layer and --chaos runs the replica pool under a seeded fault plan —
    both on the simulated clock built from locally measured stage costs."""
    from repro.core.export import calibrate_exit_threshold
    from repro.serving import (ChaosPlan, ContinuousBatchScheduler,
                               ReplicaPoolScheduler, Request, SLOPolicy)

    rng = np.random.default_rng(0)
    stream = fam.eval_batches(-(-args.requests // args.batch), args.batch)
    xs = jnp.concatenate([x for x, _ in stream])[:args.requests]
    ys = jnp.concatenate([y for x, y in stream])[:args.requests]
    threshold = args.threshold
    if threshold is None:
        threshold = calibrate_exit_threshold(model, xs[:args.slots])
        print(f'calibrated exit threshold: {threshold:.4f}')
    t = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    deadlines = [None] * args.requests
    if args.deadline_ms is not None:
        deadlines = [float(ti) + args.deadline_ms * 1e-3 for ti in t]
    reqs = [Request(i, xs[i], float(t[i]), deadline=deadlines[i])
            for i in range(args.requests)]
    simulated = args.chaos or args.deadline_ms is not None or args.pipeline
    if simulated:
        # the SLO layer and the replica pool need a deterministic clock:
        # measure per-segment batch costs locally and simulate on them
        costs = _measure_stage_costs(model, xs[:args.slots])
        print('measured stage costs: '
              + ' '.join(f'{c * 1e3:.2f}ms' for c in costs))
        slo = SLOPolicy(stage_costs=costs) \
            if args.deadline_ms is not None else None
        if args.pipeline:
            from repro.serving import PipelineParallelScheduler
            plan = None
            if args.chaos:
                horizon = max(float(t[-1]),
                              args.requests / args.slots * sum(costs))
                plan = ChaosPlan.seeded(args.chaos_seed,
                                        len(jax.devices()), horizon)
            sched = PipelineParallelScheduler(
                model, slots=args.slots, threshold=threshold,
                stage_costs=costs, max_wait=args.max_wait, chaos=plan,
                tracer=tracer)
            p = sched.placement.summary()
            print(f"placement over {p['n_devices']} devices: "
                  f"{p['assignment']} loads={p['loads']} "
                  f"balance={p['balance']} (LPT bound {p['bound']})")
        elif args.chaos:
            horizon = max(float(t[-1]),
                          args.requests / args.slots * sum(costs)
                          / args.replicas)
            plan = ChaosPlan.seeded(args.chaos_seed, args.replicas, horizon)
            sched = ReplicaPoolScheduler(
                model, slots=args.slots, threshold=threshold,
                stage_costs=costs, slo=slo, replicas=args.replicas,
                min_replicas=args.replicas, max_replicas=args.max_replicas,
                restore=lambda: model, restore_delay=costs[0], chaos=plan,
                tracer=tracer)
        else:
            sched = ContinuousBatchScheduler(
                model, slots=args.slots, threshold=threshold,
                stage_costs=costs, max_wait=args.max_wait, slo=slo,
                tracer=tracer)
    else:
        sched = ContinuousBatchScheduler(
            model, slots=args.slots, threshold=threshold,
            max_wait=args.max_wait, tracer=tracer)
    # warm EVERY stage program off the clock: threshold 2.0 means nothing
    # exits, so the warm batch traverses all segments (a real-threshold
    # warm-up could exit at head 1 and leave deeper segments uncompiled,
    # charging their jit to the first unlucky real batch's latency)
    ContinuousBatchScheduler(
        model, slots=args.slots, threshold=2.0).run_trace(
            [Request(-1 - i, xs[i], 0.0)
             for i in range(min(4, args.requests))])
    completions, metrics = sched.run_trace(reqs)
    s = metrics.summary()
    hit = sum(1 for i, c in completions.items() if c.pred == int(ys[i]))
    print(f'config={cfg.name} backend={jax.default_backend()} '
          f'slots={sched.slots} threshold={threshold:.3f}'
          + (' clock=simulated' if simulated else ''))
    print(f"served {s['n_requests']} requests at rate={args.rate:.0f}/s: "
          f"throughput={s['throughput_rps']:.0f} req/s "
          f"p50={s['p50_latency_s'] * 1e3:.2f}ms "
          f"p99={s['p99_latency_s'] * 1e3:.2f}ms "
          f"acc={hit / max(len(completions), 1):.3f}")
    print(f"  exit mix: {s['exit_mix']}  "
          f"occupancy: {s['batch_occupancy']}")
    print(f"  latency split: queue-wait p50={s['p50_queue_wait_s'] * 1e3:.2f}"
          f"ms p99={s['p99_queue_wait_s'] * 1e3:.2f}ms | execute "
          f"p50={s['p50_execute_s'] * 1e3:.2f}ms "
          f"p99={s['p99_execute_s'] * 1e3:.2f}ms")
    if 'slo' in s:
        slo_s = s['slo']
        print(f"  SLO deadline={args.deadline_ms:.1f}ms: "
              f"attainment={slo_s['attainment']:.3f} "
              f"late={slo_s['n_late']} rejected={s['n_rejected']} "
              f"degraded={s['n_degraded']} "
              f"(mix {s['degraded_exit_mix']})")
        assert slo_s['n_late'] == 0, 'never-late contract violated'
    if 'resilience' in s:
        r = s['resilience']
        print(f"  chaos: availability={s['availability']:.4f} "
              f"kills={r['kills']} failovers={r['failovers']} "
              f"straggler_flags={r['straggler_flags']} "
              f"evictions={r['evictions']} "
              f"peak_replicas={r['peak_replicas']}")
    print('  ' + metrics.telemetry_digest())
    if tracer is not None:
        from repro.obs import check_trace
        check_trace(tracer, completions, strict=True)
        tracer.write(args.trace)
        print(f'  trace: {len(tracer.spans)} spans -> {args.trace} '
              f'(open at https://ui.perfetto.dev)')


def main():
    from repro.configs.cnn import CNN_REGISTRY
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.core.passes import Trainer
    from repro.data import SyntheticImages

    ap = argparse.ArgumentParser()
    ap.add_argument('--config', default='resnet8-cifar',
                    choices=sorted(CNN_REGISTRY))
    ap.add_argument('--batch', type=int, default=64)
    ap.add_argument('--batches', type=int, default=8)
    ap.add_argument('--threshold', type=float, default=None,
                    help='exit threshold (default 0.85; --server default '
                         'calibrates on the stream)')
    ap.add_argument('--steps', type=int, default=60,
                    help='QAT fine-tune steps before export (0 = raw init)')
    ap.add_argument('--pallas', action='store_true',
                    help='force Pallas kernels (interpret mode on CPU)')
    ap.add_argument('--resident', action='store_true',
                    help='int8-resident plan: calibrate static activation '
                         'scales on the first eval batch (core/export.py)')
    ap.add_argument('--verify', nargs='?', const='strict', default=None,
                    choices=('strict', 'warn'),
                    help='run the static analyzer (repro/analysis) over '
                         'the export before serving and print the report; '
                         'strict (default) aborts on any error finding. '
                         'Implies --resident (rules read the layer plan).')
    ap.add_argument('--server', action='store_true',
                    help='request-level serving: Poisson arrivals through '
                         'the continuous-batching scheduler '
                         '(repro/serving/); implies --resident, and '
                         '--threshold none recalibrates on the stream')
    ap.add_argument('--requests', type=int, default=256,
                    help='--server: trace length')
    ap.add_argument('--rate', type=float, default=500.0,
                    help='--server: Poisson arrival rate (req/s)')
    ap.add_argument('--slots', type=int, default=32,
                    help='--server: scheduler batch slots (tile-padded)')
    ap.add_argument('--max-wait', type=float, default=0.05,
                    help='--server: run a partial batch once its oldest '
                         'request has waited this long (seconds)')
    ap.add_argument('--deadline-ms', type=float, default=None,
                    help='--server: per-request deadline after arrival; '
                         'enables the SLO layer (deadline admission + '
                         'graceful degradation through the exit heads) on '
                         'a simulated clock from measured stage costs')
    ap.add_argument('--pipeline', action='store_true',
                    help='--server: pipeline-parallel over every visible '
                         'jax device — the placement solver packs stages '
                         'onto devices by measured cost, the int8 carry '
                         'streams between them; implies --server '
                         '(simulated clock); composes with --chaos '
                         '(seeded device kill)')
    ap.add_argument('--chaos', action='store_true',
                    help='--server: run the replica pool under a seeded '
                         'fault plan (kill + straggler slowdown) and '
                         'report resilience counters; implies --server')
    ap.add_argument('--chaos-seed', type=int, default=0)
    ap.add_argument('--trace', metavar='OUT.json', default=None,
                    help='record a runtime trace (export spans + --server '
                         'scheduler spans), validate its invariants, and '
                         'write Chrome-trace JSON for Perfetto')
    ap.add_argument('--replicas', type=int, default=2,
                    help='--chaos: provisioned replica count')
    ap.add_argument('--max-replicas', type=int, default=4,
                    help='--chaos: elastic scale-up ceiling')
    args = ap.parse_args()
    if args.chaos or args.pipeline:
        args.server = True
    if args.pipeline and args.deadline_ms is not None:
        ap.error('--pipeline does not compose with --deadline-ms (the '
                 'SLO layer lives in the replica pool)')
    if args.server or args.verify:
        args.resident = True

    fam = CNNFamily(SyntheticImages())
    cfg = CNN_REGISTRY[args.config]
    params = fam.init(jax.random.key(0), cfg)
    params, cfg = fam.add_exits(jax.random.key(1), params, cfg,
                                fam.default_exit_points(cfg))
    cfg = cfg.replace(w_bits=8, a_bits=8)
    if args.steps:
        trainer = Trainer(batch=args.batch, steps=args.steps)
        params, _ = trainer.fit(fam, cfg, params)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    stream = fam.eval_batches(args.batches, args.batch)
    model = export_cnn(params, cfg, use_pallas=True if args.pallas else None,
                       calibrate=stream[0][0] if args.resident else None,
                       verify=args.verify, tracer=tracer)
    if args.verify:
        # strict mode raised inside export_cnn already; print the report
        # (incl. info findings and visible skips) either way
        print(model.analysis)
    if args.resident:
        s = model.summary()
        print(f'layer plan: {s["kernel_launches"]} kernel launches, '
              f'{s["n_fused_lowrank"]} fused low-rank, '
              f'{s["n_depthwise"]} depthwise, '
              f'fallback MACs {s["fallback_mac_fraction"]:.1%}')
    if args.server:
        return _serve_trace(model, fam, cfg, args, tracer=tracer)
    if tracer is not None:       # batch mode: export spans only
        tracer.write(args.trace)
        print(f'trace: {len(tracer.spans)} spans -> {args.trace}')
    threshold = 0.85 if args.threshold is None else args.threshold
    # warm the jit caches off the clock
    model.serve_early_exit(stream[0][0], threshold=threshold)

    stages = {s: 0 for s in cfg.exit_stages}
    hit = tot = 0
    t0 = time.perf_counter()
    for x, y in stream:
        pred, stage = model.serve_early_exit(x, threshold=threshold)
        jax.block_until_ready(pred)
        hit += int(jnp.sum(pred == y))
        tot += int(y.size)
        for s in stages:
            stages[s] += int(np.sum(np.asarray(stage) == s))
    dt = time.perf_counter() - t0

    print(f'config={cfg.name} backend={jax.default_backend()} '
          f'int8_path={"pallas" if args.pallas else "auto"}')
    print(f'served {tot} images in {dt:.3f}s '
          f'({tot / dt:.0f} img/s), acc={hit / max(tot, 1):.3f}')
    for s in sorted(stages):
        print(f'  exit@stage{s}: {stages[s] / max(tot, 1):.1%}')
    print(f'  final head:   {1 - sum(stages.values()) / max(tot, 1):.1%}')


if __name__ == '__main__':
    main()
