"""Production training launcher.

Builds the sharded train step for ``--arch`` on the local mesh (or the
production mesh under a real TPU slice), runs the fault-tolerant loop with
async checkpointing, and optionally applies the paper's compression chain
to the trained model at the end (``--compress DPQE``).

CPU demo (reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --ckpt /tmp/ckpt
Real slice: drop --smoke; the mesh comes from make_production_mesh().
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import SyntheticTokens
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.runtime import FaultTolerantLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='tinyllama-1.1b', choices=ARCH_NAMES)
    ap.add_argument('--smoke', action='store_true',
                    help='reduced config + 1x1 mesh (CPU)')
    ap.add_argument('--multi-pod', action='store_true')
    ap.add_argument('--steps', type=int, default=100)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--lr', type=float, default=3e-4)
    ap.add_argument('--ckpt', default='/tmp/repro_ckpt')
    ap.add_argument('--ckpt-every', type=int, default=25)
    ap.add_argument('--drill', action='store_true',
                    help='inject a failure mid-run (recovery drill)')
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_local_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    data = SyntheticTokens(vocab=cfg.vocab_size)
    batch0 = data.batch(jax.random.key(0), args.batch, args.seq)
    with mesh:
        fn, model, (p_aval, o_aval, p_sh, o_sh) = steps_lib.build_train_step(
            cfg, mesh, jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0),
            lr=args.lr)
        params = model.init(jax.random.key(0))
        from repro.optim import adamw
        opt_state = adamw(args.lr).init(params)

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = fn(params, opt_state, batch)
            return (params, opt_state), {
                'loss': float(metrics['loss'])}

        def batch_fn(step):
            return data.batch(jax.random.key(step), args.batch, args.seq)

        injected = {'done': False}

        def injector(step):
            if args.drill and step == args.steps // 2 \
                    and not injected['done']:
                injected['done'] = True
                from repro.runtime import SimulatedFailure
                raise SimulatedFailure('drill: simulated node loss')

        loop = FaultTolerantLoop(
            step_fn=step_fn, batch_fn=batch_fn,
            ckpt=CheckpointManager(args.ckpt, keep=3),
            ckpt_every=args.ckpt_every,
            failure_injector=injector if args.drill else None)
        (params, opt_state), end = loop.run((params, opt_state), 0,
                                            args.steps)
    losses = [e[3]['loss'] for e in loop.events if e[0] == 'step']
    print(f'finished at step {end}; restarts={loop.restarts}; '
          f'loss {losses[0]:.3f} -> {losses[-1]:.3f}')


if __name__ == '__main__':
    main()
