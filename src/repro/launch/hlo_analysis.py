"""Optimized-HLO analysis: FLOPs / HBM-traffic / collective bytes with
while-loop (scan) trip-count scaling.

XLA's HloCostAnalysis counts a while body ONCE; our models scan over layer
groups, so everything inside the scan must be scaled by the trip count
(parsed from the loop condition's comparison constant).  Scheduled HLO does
not print operand shapes inline, so we build a per-computation symbol table
(instruction name -> shape) from definition lines + computation headers and
resolve operands through it.

Per-device numbers (the HLO is the per-partition SPMD module):
  * flops — dot (2·|out|·contract) and convolution ops, recursing into
    fusions and while bodies (MXU-flops convention, as MFU is measured);
  * bytes — per-op operand+output bytes at fusion granularity (fusion
    internals live in registers/VMEM), an HBM-traffic upper-bound proxy;
  * collectives — operand bytes per collective kind.
"""
from __future__ import annotations

import re

DTYPE_BYTES = {'f64': 8, 'f32': 4, 'bf16': 2, 'f16': 2, 'f8e4m3': 1,
               'f8e5m2': 1, 's64': 8, 'u64': 8, 's32': 4, 'u32': 4,
               's16': 2, 'u16': 2, 's8': 1, 'u8': 1, 'pred': 1,
               'c64': 8, 'c128': 16, 'u4': 1, 's4': 1}

COLL_KINDS = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
              'collective-permute')

_SHAPE = r'(?:' + '|'.join(DTYPE_BYTES) + r')\[[0-9,]*\]'
SHAPE_RE = re.compile(r'\b(' + '|'.join(DTYPE_BYTES) + r')\[([0-9,]*)\]')
DEF_RE = re.compile(r'^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?)')
NAME_RE = re.compile(r'%([\w\.\-]+)')

SKIP_BYTES_OPS = (' parameter(', ' constant(', ' tuple(',
                  ' get-tuple-element(', ' bitcast(', ' after-all(',
                  ' partition-id(', ' iota(')


class Shape:
    __slots__ = ('dims', 'bytes', 'elems')

    def __init__(self, dims, dtype):
        self.dims = dims
        self.elems = 1
        for d in dims:
            self.elems *= d
        self.bytes = self.elems * DTYPE_BYTES[dtype]


def _parse_shapes(text):
    out = []
    for m in SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(',') if d)
        out.append(Shape(dims, m.group(1)))
    return out


def split_computations(hlo: str):
    comps, cur, lines = {}, None, []
    headers, entry = {}, None
    for line in hlo.splitlines():
        s = line.rstrip()
        st = s.strip()
        m = re.match(r'(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{$', st)
        if m and not st.startswith('%param'):
            if cur:
                comps[cur] = lines
            cur, lines = m.group(2), []
            headers[cur] = m.group(3)
            if m.group(1):
                entry = cur
        elif cur is not None:
            lines.append(st)
    if cur:
        comps[cur] = lines
    return comps, headers, entry


def _symtab(comp_lines, header):
    tab = {}
    # params from the header: "param_0.2: f32[256,64], param_1: ..."
    for pm in re.finditer(r'([\w\.\-]+):\s*(\(?[^,()]*(?:\([^)]*\))?)',
                          header or ''):
        shapes = _parse_shapes(pm.group(2))
        if shapes:
            tab[pm.group(1)] = shapes[0]
    for line in comp_lines:
        dm = DEF_RE.match(line)
        if not dm:
            continue
        shapes = _parse_shapes(line.split(' = ', 1)[1].split('(')[0] + '(')
        # output shape(s): everything before the opcode's '('
        rhs = line.split(' = ', 1)[1]
        head = rhs.split('(')[0]
        shapes = _parse_shapes(head)
        if shapes:
            total = sum(s.bytes for s in shapes)
            sh = shapes[0]
            if len(shapes) > 1:            # tuple: record combined bytes
                sh = Shape((0,), 'u8')
                sh.bytes = total
                sh.elems = 0
                sh.dims = ()
            tab[dm.group(1)] = sh
    return tab


def _operands(line):
    """First-level operand names inside the opcode parens."""
    m = re.search(r'\w[\w\-]*\(', line.split(' = ', 1)[-1])
    if not m:
        return []
    rest = line[line.index(m.group(0), line.find(' = ')) + len(m.group(0)):]
    depth, buf = 1, []
    for ch in rest:
        if ch == '(':
            depth += 1
        elif ch == ')':
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    args = ''.join(buf)
    # strip nested attr refs after the closing paren are excluded already
    return NAME_RE.findall(args)


def analyze(hlo: str):
    comps, headers, entry = split_computations(hlo)
    tabs = {name: _symtab(lines, headers.get(name))
            for name, lines in comps.items()}

    def shape_of(comp, name):
        sh = tabs.get(comp, {}).get(name)
        if sh is None:
            for t in tabs.values():
                if name in t:
                    return t[name]
        return sh

    def trip_count(cond_name):
        consts = [int(x) for l in comps.get(cond_name, ())
                  for x in re.findall(r'constant\((\d+)\)', l)]
        return max(consts) if consts else 1

    def dot_flops(comp, line):
        outs = _parse_shapes(line.split(' = ', 1)[1].split(' dot(')[0])
        if not outs:
            return 0.0
        out = outs[0].elems
        ops = _operands(line)
        lhs = shape_of(comp, ops[0]) if ops else None
        cm = re.search(r'lhs_contracting_dims=\{([0-9,]*)\}', line)
        contract = 1
        if lhs is not None and cm:
            for ci in cm.group(1).split(','):
                if ci:
                    contract *= lhs.dims[int(ci)]
        return 2.0 * out * contract

    def conv_flops(comp, line):
        outs = _parse_shapes(line.split(' = ', 1)[1].split(' convolution(')[0])
        if not outs:
            return 0.0
        out = outs[0].elems
        ops = _operands(line)
        kern = shape_of(comp, ops[1]) if len(ops) > 1 else None
        if kern is None:
            return 2.0 * out
        cout = 1
        dm = re.search(r'dim_labels=\w+_(\w+)->', line)
        if dm:
            for lab, dim in zip(dm.group(1), kern.dims):
                if lab == 'o':
                    cout = dim
        return 2.0 * out * kern.elems / max(cout, 1)

    def walk(name, seen):
        if name in seen:
            return 0.0, 0.0, {}
        seen = seen | {name}
        flops = bytes_ = 0.0
        coll: dict[str, float] = {}
        for line in comps.get(name, ()):
            if ' dot(' in line:
                flops += dot_flops(name, line)
            elif ' convolution(' in line:
                flops += conv_flops(name, line)
            if ' while(' in line:
                bm = re.search(r'body=%?([\w\.\-]+)', line)
                cm = re.search(r'condition=%?([\w\.\-]+)', line)
                if bm:
                    tc = trip_count(cm.group(1)) if cm else 1
                    f2, b2, c2 = walk(bm.group(1), seen)
                    flops += f2 * tc
                    bytes_ += b2 * tc
                    for k, v in c2.items():
                        coll[k] = coll.get(k, 0) + v * tc
                continue
            if ' fusion(' in line or ' call(' in line:
                km = re.search(r'(?:calls|to_apply)=%?([\w\.\-]+)', line)
                if km:
                    f2, _, c2 = walk(km.group(1), seen)
                    flops += f2
                    for k, v in c2.items():
                        coll[k] = coll.get(k, 0) + v
            matched = None
            for kind in COLL_KINDS:
                if f' {kind}(' in line or f' {kind}-start(' in line:
                    matched = kind
                    break
            if matched:
                b = sum((shape_of(name, op) or Shape((), 'u8')).bytes
                        for op in _operands(line))
                coll[matched] = coll.get(matched, 0) + b
            if ' dynamic-update-slice(' in line or \
                    'dynamic-update-slice' in line.split('=')[0]:
                # in-place update (scan carries, cache writes), possibly
                # fused with a convert: only the update operand is real
                # traffic, not the aliased full-buffer output.  For the
                # fused form, exclude the largest operand (the target).
                ops = _operands(line)
                outs = _parse_shapes(line.split(' = ', 1)[1].split('(')[0])
                out_b = sum(s.bytes for s in outs) or 1
                shs = [shape_of(name, o) for o in ops]
                # the update operand(s) are strictly smaller than the
                # aliased target buffer(s); count only those
                bytes_ += sum(s.bytes for s in shs
                              if s is not None and s.bytes < out_b / 2)
                continue
            if '=' in line and not any(s in line for s in SKIP_BYTES_OPS):
                # HBM proxy: each fusion-boundary buffer counted once where
                # produced (x2 read+write applied by the roofline script);
                # counting operands too would double-count every consumer.
                outs = _parse_shapes(line.split(' = ', 1)[1].split('(')[0])
                bytes_ += sum(s.bytes for s in outs)
        return flops, bytes_, coll

    if entry is None:
        return {'flops': 0.0, 'bytes': 0.0, 'collectives': {}}
    flops, bytes_, coll = walk(entry, frozenset())
    return {'flops': flops, 'bytes': bytes_, 'collectives': coll}
