"""Production serving launcher: continuous batched decode.

Builds the sharded serve step (sequence-sharded KV cache + shard_map'd
flash-decode merge on a real mesh), prefills a batch of requests, and
decodes with per-request termination.  CPU demo uses the reduced config on
a 1x1 mesh — same code path as the 256/512-chip dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --batch 4 --tokens 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import SyntheticTokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch import steps as steps_lib
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='gemma2-9b', choices=ARCH_NAMES)
    ap.add_argument('--smoke', action='store_true')
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=32)
    ap.add_argument('--tokens', type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh() if args.smoke else make_production_mesh()
    if cfg.arch_kind == 'encdec':
        raise SystemExit('decoder-only serving example')

    max_len = args.prompt_len + args.tokens + 8
    data = SyntheticTokens(vocab=cfg.vocab_size)
    with mesh:
        fn, model, (avals, in_sh) = steps_lib.build_serve_step(
            cfg, mesh, batch=args.batch, max_len=max_len)
        params = model.init(jax.random.key(0))
        prompt = {'tokens': data.batch(jax.random.key(1), args.batch,
                                       args.prompt_len)['tokens']}
        if cfg.arch_kind == 'vlm':
            prompt['patches'] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        _, cache = jax.jit(lambda p, b: model.prefill(p, b,
                                                      max_len=max_len))(
            params, prompt)
        tok = jnp.zeros((args.batch,), jnp.int32)
        pos0 = args.prompt_len + (cfg.frontend_tokens
                                  if cfg.arch_kind == 'vlm' else 0)
        t0 = time.perf_counter()
        for t in range(args.tokens):
            tok, cache = fn(params, tok,
                            jnp.asarray(pos0 + t, jnp.int32), cache)
        jax.block_until_ready(tok)
        dt = (time.perf_counter() - t0) / args.tokens
    print(f'{cfg.name}: {dt * 1e3:.1f} ms/token at batch {args.batch} '
          f'(mesh {dict(mesh.shape)})')


if __name__ == '__main__':
    main()
