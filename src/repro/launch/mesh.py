"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — 'pod' is an
additional pure-DP axis over the cross-pod (DCN-class) links, so the only
cross-pod collective is the gradient reduction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 host devices before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """DP axes of a mesh (everything that is not 'model')."""
    return tuple(a for a in mesh.axis_names if a != 'model')


def make_local_mesh():
    """1x1 mesh over the single local device (CPU tests)."""
    return jax.make_mesh((1, 1), ('data', 'model'))
