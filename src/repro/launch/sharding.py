"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Megatron-style TP on the 'model' axis (vocab, heads, FFN hidden, experts,
SSD heads, RG-LRU width), FSDP-style parameter sharding over the DP axes
where divisible (params and optimizer states are both far too large to
replicate for the 72B/671B archs — GSPMD inserts the per-layer all-gathers),
and batch over ('pod','data').  Decode caches are **sequence-sharded** over
'model' (plus 'data' for the batch=1 long-context cells).

Everything is path-driven over the param pytree, so the same rules cover all
10 architectures; per-arch overrides come from cfg (``shard_heads=False``
for whisper's 12 heads).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL = 'model'


def _path_keys(path):
    out = []
    for p in path:
        k = getattr(p, 'key', None)
        if k is None:
            k = getattr(p, 'idx', None)
        out.append(str(k))
    return out


def _div(n, mesh, axis) -> bool:
    return n % int(np.prod([mesh.shape[a] for a in (
        axis if isinstance(axis, tuple) else (axis,))])) == 0


def param_spec(path, leaf, cfg, mesh, *, fsdp_axes=()):
    """PartitionSpec for one parameter leaf."""
    keys = _path_keys(path)
    shape = leaf.shape
    stacked = 'blocks' in keys                # scan-stacked: leading G dim
    off = 1 if stacked else 0

    def out(*spec):
        spec = (None,) * off + spec
        # pad/truncate to rank
        spec = (spec + (None,) * len(shape))[:len(shape)]
        # drop shardings that do not divide
        fixed = []
        for dim, s in enumerate(spec):
            if s is not None and not _div(shape[dim], mesh, s):
                s = None
            fixed.append(s)
        # FSDP: shard the largest remaining replicated dim over DP axes
        if fsdp_axes and len(shape) - off >= 2:
            best, best_dim = 0, None
            for dim in range(off, len(shape)):
                if fixed[dim] is None and shape[dim] > best \
                        and _div(shape[dim], mesh, tuple(fsdp_axes)):
                    best, best_dim = shape[dim], dim
            if best_dim is not None and best >= 1024:
                fixed[best_dim] = tuple(fsdp_axes) if len(fsdp_axes) > 1 \
                    else fsdp_axes[0]
        return P(*fixed)

    name = keys[-2] if keys and keys[-1] in ('w', 'b', 'w_q', 'scale') \
        else keys[-1]
    leafname = keys[-1]

    # --- embeddings
    if 'table' in keys:
        return out(MODEL, None)
    # --- attention
    if name in ('wq', 'wk', 'wv') or (len(keys) >= 3 and keys[-3] in
                                      ('wq', 'wk', 'wv')):
        if not cfg.shard_heads:
            return out(None, None)
        return out(None, MODEL) if leafname in ('w', 'w_q') else out(MODEL)
    if name == 'wo' and 'attn' in keys or name == 'wo' and 'xattn' in keys:
        return out(MODEL, None) if leafname in ('w', 'w_q') else out(None)
    # --- MLA
    if name in ('wq_a', 'wkv_a'):
        return out(None, None)
    if name == 'wq_b':
        return out(None, MODEL) if cfg.shard_heads else out(None, None)
    if name in ('wk_b', 'wv_b'):
        return out(None, MODEL, None)             # (r, H, dn/dv): heads
    # --- MoE (expert parallelism over 'model')
    if 'moe' in keys:
        if name == 'router':
            return out(None, None)
        if name in ('wi', 'wg', 'wo') and len(shape) - off == 3:
            return out(MODEL, None, None)
    # --- dense MLP
    if name in ('wi', 'wg'):
        return out(None, MODEL) if leafname in ('w', 'w_q') else out(MODEL)
    if name == 'wo':
        return out(MODEL, None) if leafname in ('w', 'w_q') else out(None)
    # --- RG-LRU
    if 'rglru' in keys:
        if name in ('wgate', 'wx', 'w_r', 'w_i'):
            return out(None, MODEL) if leafname in ('w', 'w_q') else out(MODEL)
        if name == 'conv':
            return out(None, MODEL) if leafname == 'w' else out(MODEL)
        if leafname == 'lam':
            return out(MODEL)
    # --- Mamba-2
    if 'mamba' in keys:
        if name in ('in_proj',):
            return out(None, MODEL) if leafname in ('w', 'w_q') else out(MODEL)
        if name == 'out_proj':
            return out(MODEL, None) if leafname in ('w', 'w_q') else out(None)
        if name == 'conv':
            return out(None, MODEL) if leafname == 'w' else out(MODEL)
        if leafname in ('A_log', 'D', 'dt_bias'):
            return out(MODEL)
        if leafname == 'scale':
            return out(MODEL)
    # --- norms / scalars / everything else: replicated (modulo FSDP)
    return out(None)


def params_shardings(params, cfg, mesh, *, fsdp=True):
    fsdp_axes = tuple(a for a in mesh.axis_names if a != MODEL) if fsdp else ()
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(p, x, cfg, mesh,
                                                    fsdp_axes=fsdp_axes)),
        params)


def batch_spec(shape, mesh):
    """Shard the leading batch dim over DP axes when divisible."""
    dp = tuple(a for a in mesh.axis_names if a != MODEL)
    if _div(shape[0], mesh, dp):
        return P(dp if len(dp) > 1 else dp[0])
    if len(dp) > 1 and _div(shape[0], mesh, dp[-1]):
        return P(dp[-1])
    return P()


def batch_shardings(batch, mesh):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)), batch)


# ------------------------------------------------------------- decode caches


def cache_spec(path, leaf, cfg, mesh, *, long_ctx=False):
    """Sequence-sharded KV caches; state caches shard batch/heads."""
    keys = _path_keys(path)
    shape = leaf.shape
    stacked = 'blocks' in keys
    off = 1 if stacked else 0
    dp = tuple(a for a in mesh.axis_names if a != MODEL)
    seq_ax = (dp + (MODEL,)) if long_ctx else (MODEL,)
    bspec = None if long_ctx else (dp if len(dp) > 1 else dp[0])

    def out(*spec):
        spec = (None,) * off + spec
        spec = (spec + (None,) * len(shape))[:len(shape)]
        fixed = []
        for dim, s in enumerate(spec):
            if s is not None and not _div(shape[dim], mesh, s):
                s = None
            fixed.append(s)
        return P(*fixed)

    leafname = keys[-1]
    if leafname in ('k', 'v'):                       # (B, Sc, K, hd)
        return out(bspec, seq_ax if len(seq_ax) > 1 else seq_ax[0])
    if leafname in ('ckv', 'kr'):                    # (B, Sc, r)
        return out(bspec, seq_ax if len(seq_ax) > 1 else seq_ax[0])
    if leafname in ('slots', 'pos'):                 # (Sc,)
        return out(seq_ax if len(seq_ax) > 1 else seq_ax[0])
    if leafname == 'total':
        return out()
    if leafname == 'h' and 'conv' not in keys:       # ssm/rglru state
        if len(shape) - off >= 2:
            return out(bspec, MODEL)                 # (B, h, p, n)/(B, W)
        return out(bspec)
    if leafname == 'conv':                           # (B, k-1, C)
        return out(bspec, None, MODEL)
    return out(bspec)


def cache_shardings(cache, cfg, mesh, *, long_ctx=False):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(
            mesh, cache_spec(p, x, cfg, mesh, long_ctx=long_ctx)), cache)


def zero1_shardings(opt_state_shapes, param_shardings_tree, mesh):
    """ZeRO-1: optimizer moments additionally sharded over DP axes."""
    dp = tuple(a for a in mesh.axis_names if a != MODEL)

    def shard_moment(sh, x):
        spec = list(sh.spec) + [None] * (len(x.shape) - len(sh.spec))
        used = set()
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)):
                used.add(a)
        free = tuple(a for a in dp if a not in used)
        if not free:
            return NamedSharding(mesh, P(*spec))
        for dim, s in enumerate(spec):
            if s is None and _div(x.shape[dim], mesh, free):
                spec[dim] = free if len(free) > 1 else free[0]
                break
        return NamedSharding(mesh, P(*spec))

    import jax as _jax
    step_sh = NamedSharding(mesh, P())
    mu = _jax.tree.map(shard_moment, param_shardings_tree,
                       opt_state_shapes.mu)
    nu = _jax.tree.map(shard_moment, param_shardings_tree,
                       opt_state_shapes.nu)
    from repro.optim.adamw import AdamWState
    return AdamWState(step=step_sh, mu=mu, nu=nu)
