"""Sharded decode attention: shard_map wrappers injected into the model ctx.

The decode caches are sequence-sharded (see launch/sharding.py); each device
computes flash-decode partials over its local cache chunk and the partials
are merged with pmax/psum (softmax-merge) across the sequence axes.  This is
what lets GQA archs whose kv_heads (1–8) don't divide the 16-way model axis
still shard their caches — and what makes the 500k-context cells fit.

The math inside the shard_map body is models/attention.decode_attn_reference
with ``axis_names`` set — identical code to the single-device reference, so
the CPU tests and the production path cannot drift apart.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import PartitionSpec as P

try:                                    # jax>=0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:                      # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

from repro.models.attention import (decode_attn_reference,
                                    decode_mla_reference)


def make_decode_ctx(mesh, cfg, *, long_ctx=False):
    """ctx dict with shard_map'd decode_attn / decode_mla."""
    dp = tuple(a for a in mesh.axis_names if a != 'model')
    seq_axes = (dp + ('model',)) if long_ctx else ('model',)
    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    bspec = None if long_ctx else (dp if len(dp) > 1 else dp[0])

    def cache_specs(cache):
        """Spec pytree for a layer cache dict: seq dim sharded."""
        def one(path, leaf):
            key = str(getattr(path[-1], 'key', ''))
            if key in ('k', 'v', 'k_s', 'v_s', 'ckv', 'kr'):
                return P(bspec, seq_spec)
            if key in ('slots', 'pos'):
                return P(seq_spec)
            return P()
        import jax
        return jax.tree_util.tree_map_with_path(one, cache)

    def decode_attn(q, nk, nv, cache, cur, *, window=0, attn_softcap=0.0):
        def local(q, nk, nv, cache, cur):
            return decode_attn_reference(q, nk, nv, cache, cur,
                                         window=window,
                                         attn_softcap=attn_softcap,
                                         axis_names=seq_axes)
        cs = cache_specs(cache)
        fn = shard_map(local, mesh,
                       in_specs=(P(bspec), P(bspec), P(bspec), cs, P()),
                       out_specs=(P(bspec), cs))
        return fn(q, nk, nv, cache, cur)

    def decode_mla(q_lat, q_rope, new_ckv, new_kr, cache, cur):
        def local(q_lat, q_rope, new_ckv, new_kr, cache, cur):
            return decode_mla_reference(q_lat, q_rope, new_ckv, new_kr,
                                        cache, cur, axis_names=seq_axes)
        cs = cache_specs(cache)
        fn = shard_map(local, mesh,
                       in_specs=(P(bspec), P(bspec), P(bspec), P(bspec),
                                 cs, P()),
                       out_specs=(P(bspec), cs))
        return fn(q_lat, q_rope, new_ckv, new_kr, cache, cur)

    return {'decode_attn': decode_attn, 'decode_mla': decode_mla}
