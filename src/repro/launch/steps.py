"""Step builders: train_step / prefill_step / serve_step with full shardings.

These are the functions the dry-run lowers and the launchers run.  Each
builder returns (jitted_fn, abstract_args) so ``dryrun.py`` can
``.lower(*abstract_args).compile()`` without allocating anything.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as sh
from repro.launch.serving import make_decode_ctx
from repro.models.actsharding import make_mesh_policy, activation_sharding
from repro.models.model import build_model
from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.optim.adamw import AdamWState


def _ce_loss(logits, labels):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(ce)


def abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def build_train_step(cfg, mesh, batch_aval, *, lr=3e-4, remat=True,
                     zero1=True, fsdp=True):
    model = build_model(cfg)
    opt = adamw(lr, weight_decay=0.1)
    p_aval = abstract_params(model)
    p_sh = sh.params_shardings(p_aval, cfg, mesh, fsdp=fsdp)
    o_aval = jax.eval_shape(opt.init, p_aval)
    o_sh = (sh.zero1_shardings(o_aval, p_sh, mesh) if zero1 else
            AdamWState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh))
    b_sh = sh.batch_shardings(batch_aval, mesh)

    policy = make_mesh_policy(mesh)

    def train_step(params, opt_state, batch):
        with activation_sharding(policy):
            def loss_fn(p):
                logits = model.forward(p, batch, remat=remat)
                labels = batch['labels']
                if cfg.arch_kind == 'vlm':  # loss only over text positions
                    logits = logits[:, -labels.shape[1]:]
                return _ce_loss(logits, labels)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, {'loss': loss, 'grad_norm': gnorm}

    fn = jax.jit(train_step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, None),
                 donate_argnums=(0, 1))
    return fn, model, (p_aval, o_aval, p_sh, o_sh)


def build_prefill_step(cfg, mesh, batch_aval, *, max_len, fsdp=True):
    model = build_model(cfg)
    p_aval = abstract_params(model)
    p_sh = sh.params_shardings(p_aval, cfg, mesh, fsdp=fsdp)
    b_sh = sh.batch_shardings(batch_aval, mesh)
    batch = batch_aval['tokens'].shape[0]
    c_aval = jax.eval_shape(lambda: build_model(cfg).init_cache(batch,
                                                                max_len))
    c_sh = sh.cache_shardings(c_aval, cfg, mesh, long_ctx=False)

    policy = make_mesh_policy(mesh)

    def prefill_step(params, batch):
        with activation_sharding(policy):
            logits, cache = model.prefill(params, batch, max_len=max_len)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

    tok_sh = NamedSharding(mesh, sh.batch_spec((batch,), mesh))
    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                 out_shardings=(tok_sh, c_sh))
    return fn, model, (p_aval, p_sh)


def build_serve_step(cfg, mesh, *, batch, max_len, long_ctx=False,
                     fsdp=True, int8_weights=False):
    """One-token decode step: greedy-sample next token, update cache.

    ``int8_weights``: serve with int8-quantized matmul weights (the paper's
    Q pass at inference — halves weight HBM streaming, §Perf iteration).
    ``fsdp=False`` keeps weights TP-sharded and resident (no per-layer
    all-gather per token — the right default for latency-bound decode).
    """
    model = build_model(cfg)
    p_aval = abstract_params(model)
    if int8_weights:
        from repro.core.quantization import quantize_params_for_serving
        p_aval = jax.eval_shape(quantize_params_for_serving, p_aval)
    p_sh = sh.params_shardings(p_aval, cfg, mesh, fsdp=fsdp)
    c_aval = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    c_sh = sh.cache_shardings(c_aval, cfg, mesh, long_ctx=long_ctx)
    ctx = make_decode_ctx(mesh, cfg, long_ctx=long_ctx)
    tok_sh = NamedSharding(mesh, sh.batch_spec((batch,), mesh))
    enc_aval = None
    if cfg.arch_kind == 'encdec':
        enc_aval = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))

    policy = make_mesh_policy(mesh)

    def serve_step(params, token, cur, cache, enc=None):
        with activation_sharding(policy):
            logits, cache = model.decode_step(params, token, cur, cache,
                                              enc=enc, ctx=ctx)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

    in_sh = [p_sh, tok_sh, NamedSharding(mesh, P()), c_sh]
    avals = [p_aval, jax.ShapeDtypeStruct((batch,), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32), c_aval]
    if enc_aval is not None:
        in_sh.append(NamedSharding(mesh, sh.batch_spec(enc_aval.shape, mesh)))
        avals.append(enc_aval)
    fn = jax.jit(serve_step, in_shardings=tuple(in_sh),
                 out_shardings=(tok_sh, c_sh), donate_argnums=(3,))
    return fn, model, (avals, in_sh)
