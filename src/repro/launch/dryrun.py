import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives fail here.  Writes
per-cell JSON (memory analysis, FLOPs/bytes, per-kind collective bytes) that
benchmarks/roofline.py turns into the EXPERIMENTS.md tables.

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh pod
    python -m repro.launch.dryrun --all --mesh multipod
"""
import argparse
import json
import re
import time

_DTYPE_BYTES = {'f64': 8, 'f32': 4, 'bf16': 2, 'f16': 2, 'f8e4m3': 1,
                'f8e5m2': 1, 's64': 8, 'u64': 8, 's32': 4, 'u32': 4,
                's16': 2, 'u16': 2, 's8': 1, 'u8': 1, 'pred': 1,
                'c64': 8, 'c128': 16}

_COLL_KINDS = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
               'collective-permute')

_SHAPE_RE = re.compile(r'(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|'
                       r's16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]')


def _shape_bytes(m):
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(','):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collective_bytes(hlo: str):
    """Sum collective operand bytes from optimized HLO, scaling ops inside
    while loops (scan-over-layers) by their trip counts."""
    # split into computations
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r'(?:ENTRY )?%?([\w\.\-]+)[\w\s]*\(.*\)\s*->.*{\s*$',
                     line)
        if m and ('{' in line):
            if cur_name:
                comps[cur_name] = cur_lines
            cur_name, cur_lines = m.group(1), []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = cur_lines

    def trip_count(cond_lines):
        consts = [int(x) for l in cond_lines
                  for x in re.findall(r'constant\((\d+)\)', l)]
        return max(consts) if consts else 1

    # collective bytes directly in each computation + while calls
    def comp_bytes(name, seen):
        if name in seen:
            return {}
        seen = seen | {name}
        totals: dict[str, float] = {}
        for line in comps.get(name, ()):
            for kind in _COLL_KINDS:
                if f' {kind}(' in line or f'{kind}-start(' in line:
                    args = line.split('(', 1)[1]
                    b = sum(_shape_bytes(m)
                            for m in _SHAPE_RE.finditer(args))
                    totals[kind] = totals.get(kind, 0) + b
                    break
            m = re.search(r'while\(', line)
            if m:
                bm = re.search(r'body=%?([\w\.\-]+)', line)
                cm = re.search(r'condition=%?([\w\.\-]+)', line)
                if bm:
                    inner = comp_bytes(bm.group(1), seen)
                    tc = trip_count(comps.get(cm.group(1), ())) if cm else 1
                    for k, v in inner.items():
                        totals[k] = totals.get(k, 0) + v * tc
        return totals

    entry = None
    for line in hlo.splitlines():
        if line.startswith('ENTRY'):
            m = re.match(r'ENTRY %?([\w\.\-]+)', line)
            entry = m.group(1) if m else None
            break
    if entry is None:
        # fall back: scan whole text flat (no loop scaling)
        totals = {}
        for line in hlo.splitlines():
            for kind in _COLL_KINDS:
                if f' {kind}(' in line or f'{kind}-start(' in line:
                    args = line.split('(', 1)[1]
                    b = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(args))
                    totals[kind] = totals.get(kind, 0) + b
                    break
        return totals
    return comp_bytes(entry, frozenset())


def run_cell(arch: str, shape: str, mesh_name: str, *, fsdp=True,
             int8=False, kv8=False, out_dir='experiments/dryrun',
             extra_tag=''):
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, SHAPES
    from repro.launch import steps as steps_lib
    from repro.optim.adamw import AdamWState  # noqa: F401

    t0 = time.time()
    cfg = get_config(arch)
    if kv8:
        cfg = cfg.replace(kv_cache_bits=8)
    mesh = make_production_mesh(multi_pod=(mesh_name == 'multipod'))
    info = SHAPES[shape]
    with mesh:
        if info['kind'] == 'train':
            batch = input_specs(cfg, shape)
            fn, model, (p_aval, o_aval, p_sh, o_sh) = \
                steps_lib.build_train_step(cfg, mesh, batch, fsdp=fsdp)
            lowered = fn.lower(p_aval, o_aval, batch)
        elif info['kind'] == 'prefill':
            batch = input_specs(cfg, shape)
            fn, model, (p_aval, p_sh) = steps_lib.build_prefill_step(
                cfg, mesh, batch, max_len=info['seq'], fsdp=fsdp)
            lowered = fn.lower(p_aval, batch)
        else:
            d = input_specs(cfg, shape)
            fn, model, (avals, in_sh) = steps_lib.build_serve_step(
                cfg, mesh, batch=d['batch'], max_len=d['max_len'],
                long_ctx=d['long_ctx'], fsdp=fsdp, int8_weights=int8)
            lowered = fn.lower(*avals)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    ana = analyze(hlo)
    coll = ana['collectives']
    res = {
        'arch': arch, 'shape': shape, 'mesh': mesh_name,
        'devices': int(len(mesh.devices.flat)),
        'flops_per_device': float(ana['flops']),
        'bytes_per_device': float(ana['bytes']),
        'xla_flops_unscaled': float(cost.get('flops', -1)),
        'xla_bytes_unscaled': float(cost.get('bytes accessed', -1)),
        'memory': {
            'argument_bytes': int(getattr(mem, 'argument_size_in_bytes', -1)),
            'output_bytes': int(getattr(mem, 'output_size_in_bytes', -1)),
            'temp_bytes': int(getattr(mem, 'temp_size_in_bytes', -1)),
            'alias_bytes': int(getattr(mem, 'alias_size_in_bytes', -1)),
        },
        'collective_bytes': coll,
        'lower_s': round(t_lower, 1), 'compile_s': round(t_compile, 1),
    }
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    tag = f'{arch}__{shape}{extra_tag}.json'
    with open(os.path.join(out_dir, mesh_name, tag), 'w') as f:
        json.dump(res, f, indent=1)
    import gzip
    with gzip.open(os.path.join(out_dir, mesh_name,
                                tag[:-5] + '.hlo.gz'), 'wt') as f:
        f.write(hlo)
    print(json.dumps(res))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch')
    ap.add_argument('--shape')
    ap.add_argument('--mesh', default='pod', choices=['pod', 'multipod'])
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--no-fsdp', action='store_true')
    ap.add_argument('--int8', action='store_true')
    ap.add_argument('--kv8', action='store_true')
    ap.add_argument('--out', default='experiments/dryrun')
    ap.add_argument('--tag', default='')
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES
    from repro.launch.specs import cells
    todo = cells(ARCH_NAMES) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in todo:
        try:
            run_cell(arch, shape, args.mesh, fsdp=not args.no_fsdp,
                     int8=args.int8, kv8=args.kv8, out_dir=args.out,
                     extra_tag=args.tag)
        except Exception as e:                                # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f'FAIL {arch} {shape}: {e!r}')
    if failures:
        raise SystemExit(f'{len(failures)} cells failed: {failures}')


if __name__ == '__main__':
    main()
