"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, never allocates — the dry-run pattern.  The
four assigned shapes:

    train_4k     seq 4096,    global_batch 256   (train_step)
    prefill_32k  seq 32768,   global_batch 32    (prefill_step)
    decode_32k   ctx 32768,   global_batch 128   (serve_step, 1 new token)
    long_500k    ctx 524288,  global_batch 1     (serve_step; sub-quadratic
                                                  archs only)

Modality frontends are stubs: whisper gets precomputed frame embeddings,
internvl2 precomputed patch embeddings, as the assignment prescribes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SHAPES = {
    'train_4k': dict(kind='train', seq=4096, batch=256),
    'prefill_32k': dict(kind='prefill', seq=32768, batch=32),
    'decode_32k': dict(kind='decode', seq=32768, batch=128),
    'long_500k': dict(kind='decode', seq=524288, batch=1, long_ctx=True),
}

# archs with a sub-quadratic long-context path (SSM / recurrent / majority
# sliding-window).  Pure full-attention archs skip long_500k (see DESIGN.md).
LONG_CTX_ARCHS = {'mamba2-2.7b', 'recurrentgemma-9b', 'gemma2-9b',
                  'gemma3-12b', 'mixtral-8x7b'}


def cells(arch_names):
    """All defined (arch, shape) dry-run cells."""
    out = []
    for a in arch_names:
        for s in SHAPES:
            if s == 'long_500k' and a not in LONG_CTX_ARCHS:
                continue
            out.append((a, s))
    return out


def input_specs(cfg, shape_name: str):
    """Abstract inputs for the given cell: dict for train/prefill batches."""
    info = SHAPES[shape_name]
    B, S = info['batch'], info['seq']
    dt = jnp.dtype(cfg.dtype)
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)  # noqa: E731

    if info['kind'] in ('train', 'prefill'):
        n_front = cfg.frontend_tokens if cfg.arch_kind in ('vlm', 'encdec') \
            else 0
        batch = {}
        if cfg.arch_kind == 'vlm':
            text = S - n_front
            batch['tokens'] = tok(B, text)
            batch['patches'] = jax.ShapeDtypeStruct((B, n_front, cfg.d_model),
                                                    dt)
            batch['labels'] = tok(B, text)
        elif cfg.arch_kind == 'encdec':
            # seq budget split: encoder frames (stub embeddings) + decoder
            batch['frames'] = jax.ShapeDtypeStruct((B, min(n_front, S // 2),
                                                    cfg.d_model), dt)
            batch['tokens'] = tok(B, S)
            batch['labels'] = tok(B, S)
        else:
            batch['tokens'] = tok(B, S)
            batch['labels'] = tok(B, S)
        if info['kind'] == 'prefill':
            batch.pop('labels')
        return batch

    # decode: handled by build_serve_step's avals (cache + one token)
    return dict(batch=B, max_len=S, long_ctx=info.get('long_ctx', False))
