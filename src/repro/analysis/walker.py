"""Shared jaxpr walking — THE one implementation tests and production rules
both use (tests/test_export.py used to carry a private copy; a drifted
walker means a contract the tests check and the analyzer enforces could
silently disagree about what is in the graph).

``walk_eqns`` recurses into every sub-jaxpr a primitive carries (pjit
bodies, scan/while bodies, custom_vjp calls, pallas_call kernel bodies), so
a count over it covers the whole compiled graph, not just the top level.
"""
from __future__ import annotations


def walk_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and, recursively, in any sub-jaxpr its
    params carry (ClosedJaxpr via ``.jaxpr``, open Jaxpr via ``.eqns``)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if hasattr(v, 'jaxpr'):
                yield from walk_eqns(v.jaxpr)
            elif hasattr(v, 'eqns'):
                yield from walk_eqns(v)


def prim_count(jaxpr, name: str) -> int:
    """Number of eqns whose primitive is called ``name`` (recursive)."""
    return sum(1 for e in walk_eqns(jaxpr) if e.primitive.name == name)


def pallas_calls(jaxpr):
    """All ``pallas_call`` eqns in the graph (recursive)."""
    return [e for e in walk_eqns(jaxpr) if e.primitive.name == 'pallas_call']


def _aval_bytes(aval) -> int:
    """Bytes of an abstract value (works for MemRef/ShapedArray alike)."""
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * aval.dtype.itemsize


def pallas_call_vmem_bytes(eqn) -> int:
    """Per-grid-step VMEM-resident bytes of one ``pallas_call`` eqn.

    Sums every block mapping's block (inputs and outputs, at the operand
    dtype) plus the scratch operands (the trailing invars of the kernel
    jaxpr beyond inputs+outputs).  This is the same quantity the kernels
    size against ``tiling.VMEM_BUDGET`` at build time — recomputed here
    from the *compiled* graph, so a kernel that forgot its own fit check
    still gets caught at export.
    """
    gm = eqn.params['grid_mapping']
    total = 0
    for bm in gm.block_mappings:
        n = 1
        for d in bm.block_shape:
            try:
                n *= int(d)
            except TypeError:      # squeezed/None entries carry no extent
                continue
        total += n * bm.array_shape_dtype.dtype.itemsize
    inner = eqn.params['jaxpr']
    n_io = gm.num_inputs + gm.num_outputs
    for v in inner.invars[n_io:]:
        total += _aval_bytes(v.aval)
    return total


def pallas_call_name(eqn) -> str:
    """The kernel's debug name ('quant_matmul', 'lowrank_conv', ...)."""
    info = eqn.params.get('name_and_src_info')
    name = getattr(info, 'name', None) or str(info or 'pallas_call')
    return name.split()[0]
