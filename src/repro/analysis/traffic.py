"""Shared HBM-traffic accounting over a layer plan.

Two consumers, ONE set of per-layer byte formulas:

* ``benchmarks/roofline.py int8_serving_roofline`` — the v5e roofline's
  int8-resident activation-traffic term (:func:`boundary_bytes`);
* the ``hlo-traffic`` analyzer rule — compares the optimized-HLO buffer
  proxy (launch/hlo_analysis.py) of an export against
  :func:`predicted_hbm_bytes` and flags regressions.

The prediction is backend-aware because the two lowerings move genuinely
different bytes:

* **pallas** — every inter-layer tensor is int8 (the residency contract);
  convs additionally materialize their im2col patch matrix (M x KH*KW*CIN
  int8) in HBM, depthwise convs don't (direct kernel, no patches);
* **jnp** (CPU) — inter-layer tensors are int8 too, but *inside* a layer
  the conv carries fp32 (lax.conv on export-folded fp32 weights; CPU has
  no int8 conv units): per conv the XLA buffer proxy sees the fp32 conv
  output, the fp32 glue output, and the int8 requantized boundary
  (~9 bytes/output element), plus the fp32 padded input of the depthwise
  shift conv and the fp32 rank intermediate of factored pairs.

Calibrated against the measured HLO proxy on the CPU jnp backend
(resnet8 0.84x / vgg8 0.91x / mobilenet 0.90x / factored resnet 0.96x of
prediction), so the hlo-traffic rule's budget of prediction x (1 + tol)
holds 20%+ of slack on every shipped export while still firing on a
genuine traffic doubling.
"""
from __future__ import annotations


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def boundary_bytes(plan_layers: dict) -> dict:
    """Inter-layer (HBM-boundary) traffic of the int8-resident path.

    Per layer: int8 input + output bytes, the output at 4 bytes/element for
    declared fp32 fallback layers only.  Depthwise layers' share is
    reported separately (``depthwise_bytes``) — this is exactly the
    roofline's ``memory_s_int8_resident`` numerator.
    """
    int8_bytes = dw_bytes = 0.0
    elems_in = elems_out = 0
    for e in plan_layers.values():
        out_b = 4.0 if e.get('fallback') else 1.0
        layer = _prod(e['in_shape']) + out_b * _prod(e['out_shape'])
        int8_bytes += layer
        if e.get('depthwise'):
            dw_bytes += layer
        elems_in += _prod(e['in_shape'])
        elems_out += _prod(e['out_shape'])
    return {'int8_bytes': int8_bytes, 'depthwise_bytes': dw_bytes,
            'elems_in': elems_in, 'elems_out': elems_out}


def _patch_elems(e) -> int:
    """im2col patch-matrix elements a non-depthwise conv materializes."""
    kh, kw = e.get('kernel', (1, 1))
    b, oh, ow = e['out_shape'][0], e['out_shape'][1], e['out_shape'][2]
    return b * oh * ow * kh * kw * e['in_shape'][-1]


def predicted_hbm_bytes(plan_layers: dict, backend: str = 'jnp') -> dict:
    """Predicted XLA buffer-proxy bytes for one serving step of a resident
    export (see module docstring for the per-backend terms).  Returns the
    total plus the term breakdown so a flagged regression names what grew.
    """
    first = next(iter(plan_layers.values()))
    total = float(_prod(first['in_shape']))     # the input's int8 requantize
    terms = {'input': total}

    def add(key, v):
        nonlocal total
        terms[key] = terms.get(key, 0.0) + float(v)
        total += v

    for e in plan_layers.values():
        o = _prod(e['out_shape'])
        if e['kind'] == 'fc':
            # fp32 logits (+ the fp32 rank intermediate when factored)
            add('fc', 4 * o * (2 if e.get('factored') else 1))
            continue
        if backend == 'pallas':
            out_b = 4 if e.get('fallback') else 1
            add('boundary', _prod(e['in_shape']) + out_b * o)
            if not (e.get('depthwise') or e.get('fallback')):
                add('patches', _patch_elems(e))
        else:
            # fp32 conv out + fp32 glue out + int8 requantized boundary
            add('conv', 9 * o)
            if e.get('depthwise'):
                add('depthwise_pad', 4 * _prod(e['in_shape']))
            if e.get('factored'):
                h = e['out_shape'][0] * e['out_shape'][1] \
                    * e['out_shape'][2] * e['rank']
                add('lowrank_h', 5 * h)      # fp32 h + int8 h_q
    return {'predicted_bytes': total, 'terms': terms, 'backend': backend}
