"""CI verify gate: ``PYTHONPATH=src python -m repro.analysis.gate``.

Green side: exports every CNN config (resnet8/vgg8/mobilenet, with exit
heads) on BOTH serving backends — the Pallas path for residency/VMEM/
launch-count contracts, the jnp path for stage-carry and the HLO traffic
budget — plus the registry's theoretical pass order, and requires zero
error-severity findings.

Red side: every registered builtin rule must CATCH its mutation fixture
(:mod:`repro.analysis.mutations`).  A rule that stops firing — a walker
regression, a loosened threshold, a skipped requirement — fails CI here
even though all shipped exports still look clean.

Exit status 0 iff both sides hold.  scripts/ci.sh runs this before the
test suite.
"""
from __future__ import annotations

import sys


def _clean_targets():
    import jax
    from repro.analysis import check
    from repro.configs.cnn import (MOBILENET_SMALL_CIFAR, RESNET8_CIFAR,
                                   VGG8_CIFAR)
    from repro.core import planner
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages

    fam = CNNFamily(SyntheticImages())
    x = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
    reports = []
    for base in (RESNET8_CIFAR, VGG8_CIFAR, MOBILENET_SMALL_CIFAR):
        params = fam.init(jax.random.key(0), base)
        params, cfg = fam.add_exits(jax.random.key(2), params, base,
                                    fam.default_exit_points(base))
        cfg = cfg.replace(w_bits=8, a_bits=8)
        for use_pallas in (False, True):
            model = export_cnn(params, cfg, use_pallas=use_pallas,
                               calibrate=x)
            reports.append(check(
                model, x=x,
                target=f'{cfg.name}[{model.backend}]'))
    reports.append(check(sequence=planner.theoretical_order()))
    return reports


def _mutant_reports():
    from repro.analysis import check
    from repro.analysis.mutations import MUTANTS
    return {key: check(**factory()) for key, factory in MUTANTS.items()}


def main(argv=None) -> int:
    ok = True
    print('== verify: shipped exports must be clean ==')
    for report in _clean_targets():
        print(report)
        if not report.ok:
            ok = False
    print('\n== verify: mutated exports must FAIL their rule ==')
    for key, report in _mutant_reports().items():
        caught = any(f.severity == 'error' for f in report.by_rule(key))
        verdict = 'caught' if caught else 'MISSED (rule is dead!)'
        print(f'{report.target}: {verdict}')
        if not caught:
            print(report)
            ok = False
    print(f'\nanalysis gate: {"PASS" if ok else "FAIL"}')
    return 0 if ok else 1


if __name__ == '__main__':
    sys.exit(main())
