"""Structured output of the static analyzer: findings + report.

A :class:`Finding` is one checked fact about an export (or a pass
sequence): which rule produced it, how bad it is, where it points.  An
:class:`AnalysisReport` is the result of one ``analysis.check(...)`` run —
attached to ``ServingModel.summary()``, printed by
``launch/serve_cnn.py --verify``, and gated on by ``scripts/ci.sh``
(``python -m repro.analysis.gate`` fails on any error-severity finding).
"""
from __future__ import annotations

from dataclasses import dataclass, field

SEVERITIES = ('error', 'warn', 'info')


@dataclass(frozen=True)
class Finding:
    """One analyzer observation.  ``where`` names the layer / kernel /
    sequence position the finding anchors to (None for whole-graph facts)."""
    rule: str
    severity: str          # 'error' | 'warn' | 'info'
    message: str
    where: str | None = None

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f'unknown severity {self.severity!r} '
                             f'(one of {SEVERITIES})')

    def to_dict(self) -> dict:
        d = {'rule': self.rule, 'severity': self.severity,
             'message': self.message}
        if self.where is not None:
            d['where'] = self.where
        return d

    def __str__(self):
        loc = f' [{self.where}]' if self.where else ''
        return f'{self.severity.upper():5s} {self.rule}{loc}: {self.message}'


class AnalysisError(RuntimeError):
    """Raised by strict verification when error-severity findings exist.
    Carries the full report as ``.report``."""

    def __init__(self, report: 'AnalysisReport'):
        self.report = report
        errs = '\n'.join(f'  {f}' for f in report.errors)
        super().__init__(
            f'{len(report.errors)} error-severity analysis finding(s):\n'
            f'{errs}')


@dataclass(frozen=True)
class AnalysisReport:
    """The outcome of one analyzer run over a target."""
    findings: tuple = ()
    checked: tuple = ()        # rule keys that actually ran
    skipped: tuple = ()        # (rule key, reason) for rules that could not
    target: str = ''           # e.g. the exported config name

    @property
    def errors(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == 'error')

    @property
    def warnings(self) -> tuple:
        return tuple(f for f in self.findings if f.severity == 'warn')

    @property
    def ok(self) -> bool:
        """True iff no error-severity finding survived."""
        return not self.errors

    def by_rule(self, key: str) -> tuple:
        return tuple(f for f in self.findings if f.rule == key)

    def raise_if_errors(self) -> 'AnalysisReport':
        if not self.ok:
            raise AnalysisError(self)
        return self

    def to_dict(self) -> dict:
        return {'ok': self.ok,
                'target': self.target,
                'checked': list(self.checked),
                'skipped': [list(s) for s in self.skipped],
                'findings': [f.to_dict() for f in self.findings]}

    def __str__(self):
        head = (f'analysis[{self.target or "?"}]: '
                f'{"OK" if self.ok else "FAIL"} '
                f'({len(self.errors)} errors, {len(self.warnings)} warnings; '
                f'rules run: {", ".join(self.checked) or "none"})')
        lines = [head] + [f'  {f}' for f in self.findings]
        lines += [f'  SKIP  {k}: {why}' for k, why in self.skipped]
        return '\n'.join(lines)
