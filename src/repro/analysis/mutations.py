"""Deliberately-broken exports that each analyzer rule must catch.

Every rule ships with a mutation factory proving it is *live*: the factory
builds a target violating exactly that rule's contract and returns the
``analysis.check(...)`` kwargs to run it (restricted to the one rule, so
the red/green verdict is attributable).  tests/test_analysis.py asserts
red-on-mutant per rule, and ``python -m repro.analysis.gate`` (the
scripts/ci.sh verify stage) refuses to pass unless every mutant FAILS —
a rule that silently stops firing breaks CI, not production.

Factories are functions (not precomputed fixtures) because each performs
a real export; callers invoke only what they need.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp


def _resnet_export(*, factorize=False, use_pallas=True, exits=False):
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core.export import export_cnn
    from repro.core.family import CNNFamily
    from repro.data import SyntheticImages
    from repro.models.cnn import init_cnn
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    fam = CNNFamily(SyntheticImages())
    params = init_cnn(jax.random.key(0), cfg)
    if factorize:
        params, _, _ = fam.factorize(params, cfg, energy=0.6, min_rank=2)
    if exits:
        params, cfg = fam.add_exits(jax.random.key(2), params, cfg,
                                    fam.default_exit_points(cfg))
        cfg = cfg.replace(w_bits=8, a_bits=8)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    model = export_cnn(params, cfg, use_pallas=use_pallas, calibrate=x)
    return model, params, cfg, x


def mutant_int8_residency():
    """A 'resident' export whose graph still runs dynamic abs-max: the
    dynamic-scale serving fn grafted under a calibrated plan.  The
    int8-residency rule must flag the reduce_max eqns."""
    from repro.configs.cnn import RESNET8_CIFAR
    from repro.core.export import export_cnn
    from repro.models.cnn import init_cnn
    cfg = RESNET8_CIFAR.replace(w_bits=8, a_bits=8)
    params = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    resident = export_cnn(params, cfg, use_pallas=True, calibrate=x)
    mutant = export_cnn(params, cfg, use_pallas=True)   # dynamic scales
    mutant.plan = resident.plan            # claims residency it doesn't have
    return {'model': mutant, 'x': x, 'rules': ('int8-residency',),
            'target': 'mutant:int8-residency'}


def mutant_vmem_fit():
    """A pallas_call whose blocks + int32 accumulator scratch need ~10 MiB
    of VMEM per grid step (budget 8 MiB).  quant_matmul itself carries no
    build-time fit assert — exactly the hole the vmem-fit rule plugs."""
    from repro.kernels.quant_matmul import quant_matmul
    n = 1024           # bm=bn=bk=n: 2x1 MiB int8 blocks + 4 MiB fp32 out
    w_q = jnp.zeros((n, n), jnp.int8)      # + 4 MiB int32 acc scratch
    sw = jnp.ones((n,), jnp.float32)

    def fn(p, v):
        del p
        return quant_matmul(v, w_q, jnp.ones((n,), jnp.float32), sw,
                            bm=n, bn=n, bk=n, interpret=True)

    model = SimpleNamespace(fn=fn, fn_exits=None, params=None, plan=None,
                            backend='pallas', cfg=None, stage_fns=None)
    return {'model': model, 'x': jnp.zeros((n, n), jnp.int8),
            'rules': ('vmem-fit',), 'target': 'mutant:vmem-fit'}


def mutant_launch_budget():
    """A factored resident export whose plan claims two launches for a
    layer the graph serves fused (one pallas_call) — the classic drift
    between the launch accounting and the compiled graph."""
    model, _, _, x = _resnet_export(factorize=True, use_pallas=True)
    fused = [e for e in model.plan.layers.values()
             if e.get('fused') and e['kind'] == 'conv']
    assert fused, 'mutation needs at least one fused low-rank layer'
    fused[0]['launches'] = 2               # graph still launches once
    return {'model': model, 'x': x, 'rules': ('launch-budget',),
            'target': 'mutant:launch-budget'}


def mutant_stage_carry():
    """A stage-split export whose first segment dequantizes its carry to
    fp32 before handing it across the stage boundary — 4x the inter-stage
    HBM bytes and a broken scheduler contract."""
    model, _, _, x = _resnet_export(use_pallas=False, exits=True)
    orig = model.stage_fns[0]

    def leaky(p, h):
        exits, carry = orig(p, h)
        return exits, carry.q.astype(jnp.float32) * carry.scale

    model.stage_fns = (jax.jit(leaky),) + model.stage_fns[1:]
    return {'model': model, 'x': x, 'rules': ('stage-carry',),
            'target': 'mutant:stage-carry'}


def mutant_placement_consistency():
    """A placed stage-split export whose placement lost a stage: the last
    segment has no assigned device (and no committed params copy) — the
    exact inconsistency a buggy re-solve after a device kill would ship.
    Works on a single local device: the clean placement pins every stage
    to device 0, the mutant then truncates one assignment."""
    from dataclasses import replace
    model, _, _, x = _resnet_export(use_pallas=False, exits=True)
    dev = jax.devices()[0]
    placed = model.place_stages((dev,) * model.n_stages)
    broken = replace(placed,
                     stage_devices=placed.stage_devices[:-1] + (None,),
                     stage_params=placed.stage_params[:-1] + (None,))
    return {'model': broken, 'x': x, 'rules': ('placement-consistency',),
            'target': 'mutant:placement-consistency'}


def mutant_order_dag():
    """Quantization before pruning: 'QP' reverses the theoretical edge
    P→Q (neuron granularity precedes sub-neuron)."""
    return {'sequence': 'QP', 'rules': ('order-dag',),
            'target': 'mutant:order-dag'}


def mutant_trace_invariants():
    """A runtime trace with a torn span (t1 < t0) and two stage.exec
    spans claiming the same replica concurrently — the two ways a buggy
    scheduler most plausibly corrupts its own evidence.  The
    trace-invariants rule must flag both."""
    from repro.obs.trace import Span
    spans = [
        Span('stage.exec', 0.000, 0.004, 'replica0',
             args={'stage': 0, 'live': 8, 'slots': 8, 'rids': [0]}),
        Span('stage.exec', 0.002, 0.006, 'replica0',          # concurrent
             args={'stage': 1, 'live': 4, 'slots': 8, 'rids': [1]}),
        Span('stage.exec', 0.010, 0.008, 'replica1',          # torn
             args={'stage': 0, 'live': 8, 'slots': 8, 'rids': [2]}),
    ]
    return {'trace': spans, 'rules': ('trace-invariants',),
            'target': 'mutant:trace-invariants'}


def mutant_hlo_traffic():
    """A serving fn that silently runs the network twice (averaged over
    the input and its mirror — flip defeats CSE) under an unchanged plan:
    ~2x the predicted HBM bytes, well past the 20% budget."""
    model, _, _, x = _resnet_export(use_pallas=False)
    orig = model.fn

    def doubled(p, v):
        return 0.5 * (orig(p, v) + orig(p, jnp.flip(v, axis=1)))

    model.fn = jax.jit(doubled)
    return {'model': model, 'x': x, 'rules': ('hlo-traffic',),
            'target': 'mutant:hlo-traffic'}


#: rule key -> factory returning analysis.check(**kwargs) for a target
#: that MUST produce an error finding from exactly that rule.
MUTANTS = {
    'int8-residency': mutant_int8_residency,
    'vmem-fit': mutant_vmem_fit,
    'launch-budget': mutant_launch_budget,
    'stage-carry': mutant_stage_carry,
    'order-dag': mutant_order_dag,
    'placement-consistency': mutant_placement_consistency,
    'hlo-traffic': mutant_hlo_traffic,
    'trace-invariants': mutant_trace_invariants,
}
