"""The rule-registry static analyzer: graph contracts checked at export.

Mirrors the core/registry.py idiom — rules are registrable data
(:class:`AnalysisRule`: key + severity + requirements + check fn), a
process-global registry (:func:`register_rule` / :func:`unregister_rule` /
:func:`get_rule` / :func:`registered_rules`), and one entry point
(:func:`check`) that runs every applicable rule over a target and returns
a structured :class:`~repro.analysis.report.AnalysisReport`.  Nothing is
*executed*: rules trace jaxprs, walk eqns, read plan metadata, and (for
hlo-traffic) inspect the optimized HLO text.

Builtin rules (see README.md in this package):

=================  ========  ====================================kind=======
int8-residency     error     fp32 only at logits / declared fallbacks; zero
                             reduce_max and zero weight-scale recompute in a
                             calibrated resident graph
vmem-fit           error     every pallas_call's blocks + scratch statically
                             fit ``tiling.VMEM_BUDGET`` per grid step
launch-budget      error     pallas_call count == the layer plan's launch
                             accounting, incl. fused/chained selections
stage-carry        error     stage boundaries exchange int8 QAct with static
                             float scales; no host transfers between segments
order-dag          error     a Pipeline sequence respects every theoretical
                             order edge (``planner.theoretical_dag``)
hlo-traffic        error     optimized-HLO buffer bytes within 20% of the
                             roofline-shared prediction (jnp backend)
=================  ========  =============================================

A rule whose requirements the target cannot satisfy (e.g. order-dag with
no sequence, vmem-fit on the jnp backend) is *skipped* and recorded
as such in the report — skipping is visible, never silent.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.analysis.report import (SEVERITIES, AnalysisReport, Finding)
from repro.analysis.walker import (pallas_call_name, pallas_call_vmem_bytes,
                                   pallas_calls, prim_count, walk_eqns)

#: What a rule may declare in ``requires`` — :meth:`AnalysisContext.has`
#: answers each against the target.
KNOWN_REQUIRES = ('model', 'plan', 'pallas', 'stages', 'sequence', 'input',
                  'trace', 'placement')

#: hlo-traffic: measured bytes may exceed the prediction by this fraction
#: before the rule errors (the ISSUE's ">20% regression" threshold).
HLO_TRAFFIC_TOL = 0.20

_KEY_RE = re.compile(r'^[a-z0-9]+(-[a-z0-9]+)*$')

# primitives that bounce through the host mid-segment — a serving segment
# crossing one of these breaks the scheduler's on-device carry contract.
# NB: device_put is deliberately absent: inside a jitted graph it is a
# placement/sharding annotation on constants, not a host round-trip.
_TRANSFER_PRIMS = ('copy_to_host_async', 'io_callback', 'pure_callback',
                   'python_callback')


@dataclass(frozen=True)
class AnalysisRule:
    """A registrable graph contract: metadata + the check itself."""
    key: str             # kebab-case, e.g. 'int8-residency'
    severity: str        # default severity of this rule's findings
    requires: tuple      # subset of KNOWN_REQUIRES the target must satisfy
    doc: str             # one-line contract statement (shown in README/CLI)
    fn: Callable         # (ctx: AnalysisContext, rule) -> iterable[Finding]

    def finding(self, message: str, *, where: str | None = None,
                severity: str | None = None) -> Finding:
        """Build a finding attributed to this rule (default severity)."""
        return Finding(self.key, severity or self.severity, message, where)


# ----------------------------------------------------------------- registry


_RULES: dict[str, AnalysisRule] = {}


def register_rule(rule: AnalysisRule, *, replace: bool = False
                  ) -> AnalysisRule:
    """Register a rule under its key.  Raises on collisions unless
    ``replace=True`` (a third-party rule must not shadow silently)."""
    if not _KEY_RE.match(rule.key or ''):
        raise ValueError(f'rule key must be kebab-case '
                         f'([a-z0-9-]), got {rule.key!r}')
    if rule.severity not in SEVERITIES:
        raise ValueError(f'rule {rule.key!r}: unknown severity '
                         f'{rule.severity!r} (one of {SEVERITIES})')
    unknown = sorted(set(rule.requires) - set(KNOWN_REQUIRES))
    if unknown:
        raise ValueError(f'rule {rule.key!r}: unknown requirements '
                         f'{unknown} (known: {KNOWN_REQUIRES})')
    if not callable(rule.fn):
        raise ValueError(f'rule {rule.key!r}: fn must be callable')
    if rule.key in _RULES and not replace:
        raise ValueError(f'rule key {rule.key!r} already registered; '
                         f'use replace=True')
    _RULES[rule.key] = rule
    return rule


def unregister_rule(key: str) -> AnalysisRule:
    """Remove and return a registered rule (tests round-trip through it)."""
    try:
        return _RULES.pop(key)
    except KeyError:
        raise KeyError(f'rule {key!r} is not registered '
                       f'(have {registered_rules()})') from None


def get_rule(key: str) -> AnalysisRule:
    try:
        return _RULES[key]
    except KeyError:
        raise KeyError(f'unknown rule {key!r} '
                       f'(registered: {registered_rules()})') from None


def registered_rules() -> tuple:
    """All registered rule keys, sorted alphabetically."""
    return tuple(sorted(_RULES))


# ------------------------------------------------------------------ context


class AnalysisContext:
    """Lazy, cached views of the analysis target.

    Jaxpr traces, the weight-scale-recompute delta, and the optimized HLO
    text are each produced at most once no matter how many rules read them
    — tracing a resident export is cheap (~100ms) but not free, and the
    HLO compile is the expensive one (~1s on the CPU backend).
    """

    def __init__(self, model=None, sequence=None, x=None, trace=None,
                 completions=None):
        self.model = model
        self.sequence = sequence
        self.trace = trace                # Tracer, span list, or trace path
        self.completions = completions    # {rid: Completion} (optional)
        self._x = x
        self._jaxprs: dict[str, Any] = {}
        self._scale_delta: int | None = None
        self._hlo: str | None = None

    # -- capability probes (rule `requires`) --

    def has(self, req: str) -> bool:
        if req == 'model':
            return self.model is not None
        if req == 'plan':
            return getattr(self.model, 'plan', None) is not None
        if req == 'pallas':
            return getattr(self.model, 'backend', None) == 'pallas'
        if req == 'stages':
            return bool(getattr(self.model, 'stage_fns', None))
        if req == 'sequence':
            return self.sequence is not None
        if req == 'input':
            return self.example_input() is not None
        if req == 'trace':
            return self.trace is not None
        if req == 'placement':
            return bool(getattr(self.model, 'stage_devices', None))
        raise ValueError(f'unknown requirement {req!r} '
                         f'(known: {KNOWN_REQUIRES})')

    def missing(self, rule: AnalysisRule) -> list:
        return [r for r in rule.requires if not self.has(r)]

    # -- target views --

    def example_input(self):
        """The abstract serving input: caller-provided, else derived from
        the resident plan's first layer (its recorded calibration
        geometry)."""
        if self._x is None and getattr(self.model, 'plan', None) is not None:
            first = next(iter(self.model.plan.layers.values()))
            self._x = jnp.zeros(first['in_shape'], jnp.float32)
        return self._x

    def sequence_str(self) -> str:
        """The pass-key string of the target sequence (accepts a raw
        string or anything with a ``.sequence`` — e.g. chain.Pipeline)."""
        return getattr(self.sequence, 'sequence', self.sequence)

    def _trace(self, which: str):
        if which not in self._jaxprs:
            from repro.core import quantization
            m = self.model
            fn = m.fn if which == 'fn' else m.fn_exits
            before = quantization.WEIGHT_SCALE_COMPUTATIONS[0]
            jx = jax.make_jaxpr(lambda p, v: fn(p, v))(
                m.params, self.example_input())
            delta = quantization.WEIGHT_SCALE_COMPUTATIONS[0] - before
            if self._scale_delta is None:
                self._scale_delta = delta
            self._jaxprs[which] = jx.jaxpr
        return self._jaxprs[which]

    def jaxpr_fn(self):
        return self._trace('fn')

    def jaxpr_exits(self):
        return self._trace('exits')

    def main_jaxpr(self):
        """(jaxpr, label) of the widest serving graph — ``fn_exits`` when
        exported, else ``fn`` — so checks cover the exit heads too."""
        if getattr(self.model, 'fn_exits', None) is not None:
            return self.jaxpr_exits(), 'fn_exits'
        return self.jaxpr_fn(), 'fn'

    def n_heads(self) -> int:
        """fp32 logit heads the main jaxpr legitimately emits."""
        if getattr(self.model, 'fn_exits', None) is None:
            return 1
        cfg = getattr(self.model, 'cfg', None)
        return 1 + len(tuple(getattr(cfg, 'exit_stages', ()) or ()))

    def scale_delta(self) -> int:
        """Weight-scale recomputations observed while tracing the serving
        fn (quantization.WEIGHT_SCALE_COMPUTATIONS delta; must be 0)."""
        if self._scale_delta is None:
            self.main_jaxpr()
        return self._scale_delta

    def hlo_text(self) -> str:
        if self._hlo is None:
            m = self.model
            self._hlo = jax.jit(lambda p, v: m.fn(p, v)).lower(
                m.params, self.example_input()).compile().as_text()
        return self._hlo


# -------------------------------------------------------------- entry point


def check(model=None, *, sequence=None, x=None, rules=None,
          strict: bool = False, target: str = '', trace=None,
          completions=None) -> AnalysisReport:
    """Run every applicable registered rule over the target.

    ``model`` — a ServingModel (or anything shaped like one);
    ``sequence`` — a pass-key string or Pipeline for the order-dag rule;
    ``x`` — example input override (derived from the plan when omitted);
    ``rules`` — restrict to these keys (default: all registered);
    ``strict`` — raise :class:`AnalysisError` on any error finding;
    ``trace`` — runtime evidence for the trace-invariants rule: a
    ``repro.obs.Tracer``, a span list, or a Chrome-trace file path, with
    ``completions`` (``{rid: Completion}``) enabling the latency-extent
    checks.

    Rules whose requirements the target cannot satisfy are recorded under
    ``report.skipped`` with the unmet requirement — not silently dropped.
    """
    ctx = AnalysisContext(model=model, sequence=sequence, x=x, trace=trace,
                          completions=completions)
    keys = tuple(rules) if rules is not None else registered_rules()
    findings, checked, skipped = [], [], []
    for key in keys:
        rule = get_rule(key)
        missing = ctx.missing(rule)
        if missing:
            skipped.append((key, f'target lacks {"/".join(missing)}'))
            continue
        findings.extend(rule.fn(ctx, rule))
        checked.append(key)
    if not target:
        cfg = getattr(model, 'cfg', None)
        target = getattr(cfg, 'name', None) or \
            (f'sequence {ctx.sequence_str()!r}' if sequence is not None
             else 'trace' if trace is not None else 'model')
    report = AnalysisReport(findings=tuple(findings), checked=tuple(checked),
                            skipped=tuple(skipped), target=target)
    if strict:
        report.raise_if_errors()
    return report


# ------------------------------------------------------------ builtin rules


def _rule_int8_residency(ctx: AnalysisContext, rule: AnalysisRule):
    """fp32 appears only at logit heads / declared fallbacks; no dynamic
    activation abs-max (reduce_max) and no weight-scale recompute survive
    in a calibrated resident graph."""
    out = []
    jaxpr, label = ctx.main_jaxpr()
    n_rm = prim_count(jaxpr, 'reduce_max')
    if n_rm:
        out.append(rule.finding(
            f'{n_rm} reduce_max eqn(s) in the calibrated resident graph — '
            f'an activation abs-max runs at serve time (activation scales '
            f'must be static calibration constants)', where=label))
    if ctx.scale_delta():
        out.append(rule.finding(
            f'{ctx.scale_delta()} weight-scale recomputation(s) while '
            f'tracing the serving fn — weight scales must be snapshotted '
            f'at export, not derived per call', where=label))
    model = ctx.model
    from repro.kernels.depthwise_conv import fits_depthwise
    for name, e in model.plan.layers.items():
        if e.get('fallback') and e.get('w_shape') is not None \
                and fits_depthwise(e['w_shape']):
            out.append(rule.finding(
                f'layer declares an fp32 grouped-conv fallback but its '
                f'weight {e["w_shape"]} fits the int8 depthwise kernel — '
                f'resident routing regressed (fallback is reserved for '
                f'per-group depth > 1)', where=name))
    if getattr(model, 'backend', None) != 'pallas':
        # jnp (CPU) backend: convs legitimately carry fp32 inside a layer
        # (no int8 conv units); the static-scale checks above are the
        # whole residency contract here
        return out
    calls = pallas_calls(jaxpr)
    if not calls:
        out.append(rule.finding(
            'pallas-backend export contains zero pallas_call eqns — the '
            'resident path is not routing through the kernels',
            where=label))
        return out
    for e in calls:
        dt = e.invars[0].aval.dtype
        if dt != jnp.int8:
            out.append(rule.finding(
                f'kernel {pallas_call_name(e)} consumes {dt} activations '
                f'(int8 expected at every kernel boundary)',
                where=pallas_call_name(e)))
    out_dtypes = [v.aval.dtype for e in calls for v in e.outvars]
    bad = sorted({str(d) for d in out_dtypes
                  if d not in (jnp.int8, jnp.float32)})
    if bad:
        out.append(rule.finding(
            f'kernel outputs of dtype {bad} — only int8 boundaries and '
            f'fp32 logits are allowed', where=label))
    n_fp32 = sum(1 for d in out_dtypes if d == jnp.float32)
    n_heads = ctx.n_heads()
    if n_fp32 > n_heads:
        out.append(rule.finding(
            f'{n_fp32} fp32 kernel outputs but only {n_heads} logit '
            f'head(s) — an inter-layer boundary leaks fp32 into HBM',
            where=label))
    allowed_convs = sum(1 for e in model.plan.layers.values()
                        if e.get('fallback'))
    n_fp32_convs = sum(
        1 for e in walk_eqns(jaxpr)
        if e.primitive.name == 'conv_general_dilated'
        and e.outvars[0].aval.dtype == jnp.float32)
    if n_fp32_convs > allowed_convs:
        out.append(rule.finding(
            f'{n_fp32_convs} fp32 conv eqn(s) vs {allowed_convs} declared '
            f'fallback layer(s) — an undeclared conv dodged the int8 '
            f'kernels', where=label))
    return out


def _rule_vmem_fit(ctx: AnalysisContext, rule: AnalysisRule):
    """Every pallas_call's block specs + scratch statically fit the VMEM
    budget — Mosaic OOM caught at export, not at first launch."""
    from repro.kernels.tiling import VMEM_BUDGET
    out = []
    jaxpr, label = ctx.main_jaxpr()
    for e in pallas_calls(jaxpr):
        b = pallas_call_vmem_bytes(e)
        if b > VMEM_BUDGET:
            out.append(rule.finding(
                f'kernel {pallas_call_name(e)} holds {b / 2**20:.1f} MiB '
                f'in VMEM per grid step (blocks + scratch), budget '
                f'{VMEM_BUDGET / 2**20:.0f} MiB — Mosaic would OOM this '
                f'launch', where=pallas_call_name(e)))
    return out


def _rule_launch_budget(ctx: AnalysisContext, rule: AnalysisRule):
    """pallas_call counts in the compiled graphs match the layer plan's
    launch accounting, and each factored layer's recorded launches agree
    with its fused/chained selection."""
    out = []
    model = ctx.model
    s = model.plan.summary()
    for name, e in model.plan.layers.items():
        if not (e.get('factored') and e['kind'] == 'conv'):
            continue
        want = 1 if e.get('fused') else 2
        if e.get('launches') != want:
            out.append(rule.finding(
                f'plan records {e.get("launches")} launch(es) for a '
                f'{"fused" if e.get("fused") else "chained"} factored '
                f'layer (expected {want})', where=name))
        sel = e.get('selection') or {}
        choice = sel.get('choice')
        if choice and (choice == 'fused') != bool(e.get('fused')):
            out.append(rule.finding(
                f'plan serves the layer {"fused" if e.get("fused") else "chained"} '
                f'but its recorded selection chose {choice!r} — the '
                f'shipped lowering contradicts the cost decision',
                where=name))
        if 'fused_us' in sel and 'chained_us' in sel:
            want = ('fused' if sel['fused_us'] <= sel['chained_us']
                    else 'chained')
            if choice != want:
                out.append(rule.finding(
                    f'selection chose {choice!r} but its own costs say '
                    f'{want!r} (fused {sel["fused_us"]:.1f}us vs chained '
                    f'{sel["chained_us"]:.1f}us) — the cost model and the '
                    f'decision disagree', where=name))
    if getattr(model, 'backend', None) != 'pallas':
        # jnp backend has no pallas_call eqns to count; the plan-internal
        # launch/selection consistency above is still enforced
        return out
    got = prim_count(ctx.jaxpr_fn(), 'pallas_call')
    if got != s['kernel_launches']:
        out.append(rule.finding(
            f'{got} pallas_call eqn(s) in fn vs {s["kernel_launches"]} '
            f'planned kernel launches', where='fn'))
    if getattr(model, 'fn_exits', None) is not None:
        got_ex = prim_count(ctx.jaxpr_exits(), 'pallas_call')
        want_ex = s['kernel_launches'] + s['exit_head_launches']
        if got_ex != want_ex:
            out.append(rule.finding(
                f'{got_ex} pallas_call eqn(s) in fn_exits vs {want_ex} '
                f'planned (main + exit heads)', where='fn_exits'))
    return out


def _rule_stage_carry(ctx: AnalysisContext, rule: AnalysisRule):
    """Every stage boundary exchanges an int8 QAct with a static float
    scale, and no segment crosses a host-transfer primitive — the
    continuous-batching scheduler's carry contract."""
    from repro.core.export import QAct
    out = []
    model = ctx.model
    carry = ctx.example_input()
    n = len(model.stage_fns)
    for i, fn in enumerate(model.stage_fns):
        jx = jax.make_jaxpr(lambda p, h, _f=fn: _f(p, h))(model.params,
                                                          carry)
        hosts = sorted({e.primitive.name for e in walk_eqns(jx.jaxpr)
                        if e.primitive.name in _TRANSFER_PRIMS
                        or 'callback' in e.primitive.name})
        if hosts:
            out.append(rule.finding(
                f'segment {i} crosses host-transfer primitive(s) {hosts} '
                f'— stage carries must stay on device', where=f'stage{i}'))
        res = jax.eval_shape(fn, model.params, carry)
        if i == n - 1:
            break
        _, carry = res
        if not isinstance(carry, QAct):
            leaves = jax.tree_util.tree_leaves(carry)
            dts = sorted({str(v.dtype) for v in leaves})
            out.append(rule.finding(
                f'segment {i} carries {type(carry).__name__} of dtype '
                f'{dts} across the stage boundary — must be an int8 QAct '
                f'(fp32 carries quadruple inter-stage HBM traffic and '
                f'break the scheduler contract)', where=f'stage{i}'))
        else:
            if carry.q.dtype != jnp.int8:
                out.append(rule.finding(
                    f'segment {i} QAct carry holds {carry.q.dtype} codes '
                    f'(int8 expected)', where=f'stage{i}'))
            if not isinstance(carry.scale, float):
                out.append(rule.finding(
                    f'segment {i} QAct scale is {type(carry.scale).__name__}'
                    f' — scales must be static Python floats baked at '
                    f'calibration, not traced values', where=f'stage{i}'))
    return out


def _rule_order_dag(ctx: AnalysisContext, rule: AnalysisRule):
    """A pass sequence respects every edge of the theoretical order DAG
    (static before dynamic, large before small granularity) — the paper's
    contribution, linted before any training happens."""
    from repro.core import planner, registry
    seq = ctx.sequence_str()
    out = []
    known = [k for k in seq if k in registry.registered_keys()]
    for k in sorted(set(seq) - set(known)):
        out.append(rule.finding(
            f'pass key {k!r} is not registered — the order DAG cannot '
            f'cover it', where=k, severity='warn'))
    for a, b in planner.theoretical_dag(''.join(known)):
        # edge (a, b): every a must run before any b; with repeats allowed
        # a b occurring before the LAST a is still a violation
        if seq.index(b) < seq.rindex(a):
            pa, pb = registry.get_pass(a), registry.get_pass(b)
            out.append(rule.finding(
                f"sequence {seq!r} runs '{b}' before '{a}', violating the "
                f"theoretical edge {a}→{b} ({pa.name} is "
                f"{pa.kind}/{pa.granularity}, {pb.name} is "
                f"{pb.kind}/{pb.granularity}: static precedes dynamic, "
                f"large granularity precedes small)",
                where=f'{a}->{b}'))
    return out


def _rule_hlo_traffic(ctx: AnalysisContext, rule: AnalysisRule):
    """Optimized-HLO buffer bytes (launch/hlo_analysis.py proxy) stay
    within HLO_TRAFFIC_TOL of the roofline-shared per-layer prediction
    (analysis/traffic.py) — a silent activation-traffic regression fails
    the export."""
    from repro.analysis import traffic
    from repro.launch import hlo_analysis
    model = ctx.model
    if getattr(model, 'backend', None) != 'jnp':
        return [rule.finding(
            'interpret-mode Pallas HLO is not representative of device '
            'HBM traffic (kernel bodies inline as giant fp32 loops); '
            'traffic is budgeted on the jnp export of the same plan',
            where='hlo', severity='info')]
    measured = hlo_analysis.analyze(ctx.hlo_text())['bytes']
    main = {n: e for n, e in model.plan.layers.items()
            if not n.startswith('exit')}
    pred = traffic.predicted_hbm_bytes(main, backend='jnp')
    predicted = pred['predicted_bytes']
    ratio = measured / max(predicted, 1.0)
    out = [rule.finding(
        f'HLO buffer proxy {measured / 1e6:.2f} MB vs predicted '
        f'{predicted / 1e6:.2f} MB ({ratio:.2f}x)', where='hlo',
        severity='info')]
    if measured > predicted * (1.0 + HLO_TRAFFIC_TOL):
        top = sorted(pred['terms'].items(), key=lambda kv: -kv[1])[:3]
        out.append(rule.finding(
            f'HLO buffer bytes {measured / 1e6:.2f} MB exceed the '
            f'predicted {predicted / 1e6:.2f} MB by more than '
            f'{HLO_TRAFFIC_TOL:.0%} ({ratio:.2f}x) — an HBM-traffic '
            f'regression shipped (largest predicted terms: '
            + ', '.join(f'{k}={v / 1e6:.2f}MB' for k, v in top) + ')',
            where='hlo'))
    return out


def _rule_placement_consistency(ctx: AnalysisContext, rule: AnalysisRule):
    """A placed export (``ServingModel.place_stages``) is internally
    consistent: every stage is assigned exactly one device, the committed
    per-stage params actually live on their assigned devices, and every
    *cross-device* stage edge streams an int8 QAct carry — the
    pipeline-parallel scheduler's placement contract."""
    from repro.core.export import QAct
    out = []
    model = ctx.model
    sd = tuple(model.stage_devices)
    n = model.n_stages
    if len(sd) != n:
        out.append(rule.finding(
            f'placement assigns {len(sd)} of {n} stages — every stage '
            f'must have exactly one device', where='placement'))
    for i, d in enumerate(sd[:n]):
        if d is None or isinstance(d, (tuple, list, set, frozenset)):
            out.append(rule.finding(
                f'stage {i} is assigned {d!r} — exactly one device per '
                f'stage', where=f'stage{i}'))
    sp = getattr(model, 'stage_params', None)
    if sp is None or len(sp) != len(sd):
        out.append(rule.finding(
            'stage_devices declared but stage params are not committed '
            'per stage (place_stages was bypassed)', where='placement'))
    else:
        for i, d in enumerate(sd[:n]):
            if d is None or isinstance(d, (tuple, list, set, frozenset)):
                continue
            leaves = jax.tree_util.tree_leaves(sp[i])
            devs = {dd for leaf in leaves[:1]
                    for dd in getattr(leaf, 'devices', lambda: ())()}
            if devs and devs != {d}:
                out.append(rule.finding(
                    f'stage {i} params committed to {sorted(map(str, devs))}'
                    f' but the stage is placed on {d} — the segment would '
                    f'execute off its assigned device', where=f'stage{i}'))
    # cross-device edges: the streamed carry must be an int8 QAct
    carry = ctx.example_input()
    for i, fn in enumerate(model.stage_fns[:len(sd)]):
        res = jax.eval_shape(fn, model.params, carry)
        if i >= n - 1 or i + 1 >= len(sd):
            break
        _, carry = res
        if sd[i] is sd[i + 1] or sd[i] == sd[i + 1]:
            continue
        if not isinstance(carry, QAct) or carry.q.dtype != jnp.int8:
            dts = sorted({str(v.dtype)
                          for v in jax.tree_util.tree_leaves(carry)})
            out.append(rule.finding(
                f'cross-device edge stage {i} ({sd[i]}) -> stage {i + 1} '
                f'({sd[i + 1]}) streams {type(carry).__name__} of dtype '
                f'{dts} — inter-device carries must be int8 QAct '
                f'(fp32 quadruples the transfer bytes)',
                where=f'stage{i}->stage{i + 1}'))
    return out


def _rule_trace_invariants(ctx: AnalysisContext, rule: AnalysisRule):
    """Runtime evidence: a recorded scheduler/export trace must satisfy the
    span invariants (well-formed times, proper nesting, one batch at a
    time per replica, and — with completions — every completion's latency
    equal to its span tree's extent).  The static rules check graphs; this
    one checks an execution actually recorded."""
    from repro.obs.validate import check_trace
    try:
        violations = check_trace(ctx.trace, completions=ctx.completions)
    except ValueError as e:               # torn async pair at load time
        violations = [str(e)]
    out = [rule.finding(v, where='trace') for v in violations]
    n = len(getattr(ctx.trace, 'spans', ctx.trace)) \
        if not isinstance(ctx.trace, (str, bytes)) else '?'
    out.append(rule.finding(
        f'{n} spans checked, {len(violations)} invariant violation(s)',
        where='trace', severity='info'))
    return out


def _register_builtin_rules():
    for key, requires, doc, fn in (
        ('int8-residency', ('model', 'plan', 'input'),
         'fp32 only at logit heads / declared fallbacks; zero reduce_max '
         'and zero weight-scale recompute in calibrated resident graphs',
         _rule_int8_residency),
        ('vmem-fit', ('model', 'pallas', 'input'),
         "every pallas_call's block specs + scratch statically fit "
         'tiling.VMEM_BUDGET per grid step',
         _rule_vmem_fit),
        ('launch-budget', ('model', 'plan', 'input'),
         "pallas_call counts match the layer plan's launch accounting, "
         'incl. fused/chained low-rank selections (graph counting on the '
         'pallas backend; plan-internal consistency on any backend)',
         _rule_launch_budget),
        ('stage-carry', ('model', 'plan', 'stages', 'input'),
         'stage boundaries exchange int8 QAct with static float scales; '
         'no host transfers between serving segments',
         _rule_stage_carry),
        ('order-dag', ('sequence',),
         "a Pipeline sequence respects planner.theoretical_dag's edges "
         '(reports the violated edge)',
         _rule_order_dag),
        ('hlo-traffic', ('model', 'plan', 'input'),
         'optimized-HLO buffer bytes within 20% of the roofline-shared '
         'per-layer prediction (jnp backend)',
         _rule_hlo_traffic),
        ('placement-consistency', ('model', 'stages', 'placement', 'input'),
         'every stage of a placed export is assigned exactly one device, '
         'stage params are committed where their stage runs, and every '
         'cross-device stage edge streams an int8 QAct carry',
         _rule_placement_consistency),
        ('trace-invariants', ('trace',),
         'a recorded runtime trace satisfies the span invariants: '
         'well-formed nesting, serial per-replica execution, and '
         'completion latencies that match their span extents '
         '(repro.obs.check_trace)',
         _rule_trace_invariants),
    ):
        register_rule(AnalysisRule(key=key, severity='error',
                                   requires=requires, doc=doc, fn=fn))


_register_builtin_rules()
