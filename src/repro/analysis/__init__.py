"""Static verification layer: graph contracts for every export.

``analysis.check(model, sequence=..., ...)`` walks exported serving jaxprs
and optimized HLO — executing nothing — and enforces the repo's hard-won
guarantees as registered, typed rules (see README.md here):
int8-residency, vmem-fit, launch-budget, stage-carry, order-dag,
hlo-traffic.  Wired into ``export_cnn(..., verify=)``,
``launch/serve_cnn.py --verify``, and the ``scripts/ci.sh`` gate
(``python -m repro.analysis.gate``), which also proves every rule live
against the deliberately-broken exports in :mod:`.mutations`.
"""
from repro.analysis.report import (SEVERITIES, AnalysisError, AnalysisReport,
                                   Finding)
from repro.analysis.rules import (AnalysisContext, AnalysisRule, check,
                                  get_rule, register_rule, registered_rules,
                                  unregister_rule)
from repro.analysis.walker import (pallas_call_name, pallas_call_vmem_bytes,
                                   pallas_calls, prim_count, walk_eqns)

__all__ = [
    'SEVERITIES', 'AnalysisError', 'AnalysisReport', 'Finding',
    'AnalysisContext', 'AnalysisRule', 'check', 'get_rule', 'register_rule',
    'registered_rules', 'unregister_rule',
    'pallas_call_name', 'pallas_call_vmem_bytes', 'pallas_calls',
    'prim_count', 'walk_eqns',
]
