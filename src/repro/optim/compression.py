"""Gradient compression for the data-parallel all-reduce.

int8 quantize (per-tensor scale) with error-feedback residual accumulation
(1-bit-Adam-style): the DP all-reduce then moves 4x fewer bytes.  Used by the
training step builder when ``grad_compression='int8'`` — the all-reduce is
performed on the int8 payload inside shard_map, and the error residual keeps
convergence unbiased in expectation.

This is a *distributed-optimization trick* knob (off by default): the paper's
Q pass quantizes weights/activations; compressing gradient traffic is the
communication-side analogue on a 1000-node DP fleet.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress_grads(grads, residual):
    """Returns (q_int8, scales, new_residual). residual=None initializes."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def comp(g, r):
        g = g.astype(jnp.float32) + r
        s = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / s), -128, 127).astype(jnp.int8)
        return q, s, g - q.astype(jnp.float32) * s

    flat = jax.tree.map(comp, grads, residual)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3  # noqa: E731
    q = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
    s = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
    r = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
    return q, s, r


def int8_decompress(q, s):
    return jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, s)


def allreduce_compressed(grads, residual, axis_names):
    """psum int8-compressed gradients inside shard_map; returns mean grads."""
    q, s, r = int8_compress_grads(grads, residual)
    n = 1
    for ax in axis_names:
        n *= jax.lax.axis_size(ax)
    summed = jax.tree.map(
        lambda qi: jax.lax.psum(qi.astype(jnp.int32), axis_names), q)
    # scales differ per replica -> psum the dequantized payload would lose the
    # compression; instead use the max scale (conservative, still int8 wire).
    s_max = jax.tree.map(lambda si: jax.lax.pmax(si, axis_names), s)
    mean = jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si / n,
                        summed, s_max)
    return mean, r
