from repro.optim.adamw import (adamw, apply_updates, cosine_schedule,
                               clip_by_global_norm)  # noqa: F401
from repro.optim.compression import int8_compress_grads  # noqa: F401
