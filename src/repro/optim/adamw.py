"""AdamW + schedules, dependency-free (no optax in the container).

API mirrors optax: ``opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * warm * cos
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jax.tree.map(  # noqa: E731
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                          nu=zeros(params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / (1 - b1 ** stepf)
            vhat = v / (1 - b2 ** stepf)
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
