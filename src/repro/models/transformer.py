"""Transformer assembly: decoder LM / encoder-decoder / VLM over any
``ModelConfig``.

Layers are grouped into (prefix, scanned-groups, tail):
  * ``prefix`` — the leading ``first_dense_layers`` (deepseek-v3) unrolled,
  * ``blocks`` — the repeating ``block_pattern`` unit stacked over G groups
    and executed with ``jax.lax.scan`` (keeps HLO size O(pattern) instead of
    O(layers) — essential for 61–80-layer archs compiling on a 512-way mesh),
  * ``tail`` — remainder layers unrolled.

Three entry points per model: ``forward`` (train), ``prefill`` (forward +
cache build), ``decode_step`` (one token).  ``ctx`` carries launcher
injections (shard_map'd decode attention) and defaults to pure single-device
reference math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.actsharding import shard_act
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import recurrent as rec
from repro.models.layers import (dense, embed, init_dense, init_embedding,
                                 init_mlp, init_norm, mlp, rms_norm, softcap,
                                 unembed)

# ---------------------------------------------------------------- structure


def layer_groups(cfg: ModelConfig):
    """(n_prefix, n_groups, pattern_len, n_tail) split of the layer stack."""
    P = len(cfg.block_pattern)
    n_prefix = cfg.first_dense_layers
    rest = cfg.num_layers - n_prefix
    return n_prefix, rest // P, P, rest % P


def _is_moe_layer(cfg, abs_idx):
    return cfg.is_moe and abs_idx >= cfg.first_dense_layers


# --------------------------------------------------------------------- init


def _init_layer(key, cfg, kind, *, moe_layer, cross=False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {'norm1': init_norm(cfg.d_model, dtype)}
    if kind in ('global', 'local', 'encoder'):
        p['attn'] = (attn.init_mla(ks[0], cfg, dtype) if cfg.use_mla
                     else attn.init_attention(ks[0], cfg, dtype))
    elif kind == 'recurrent':
        p['rglru'] = rec.init_rglru(ks[0], cfg, dtype)
    elif kind == 'ssm':
        p['mamba'] = rec.init_mamba2(ks[0], cfg, dtype)
        return p                                   # mamba block has no MLP
    else:
        raise ValueError(kind)
    if cross:
        p['norm_x'] = init_norm(cfg.d_model, dtype)
        p['xattn'] = attn.init_attention(ks[2], cfg, dtype)
    p['norm2'] = init_norm(cfg.d_model, dtype)
    if moe_layer:
        p['moe'] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p['mlp'] = init_mlp(ks[1], cfg, gated=cfg.family != 'audio', dtype=dtype)
    return p


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    n_prefix, G, P, R = layer_groups(cfg)
    kinds = cfg.layer_kinds()
    ks = jax.random.split(key, 8)
    params = {'embed': init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
              'final_norm': init_norm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params['unembed'] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model,
                                           dtype)
    cross = cfg.arch_kind == 'encdec'
    params['prefix'] = [
        _init_layer(k, cfg, kinds[i], moe_layer=False, cross=cross, dtype=dtype)
        for i, k in enumerate(jax.random.split(ks[2], max(n_prefix, 1))[:n_prefix])]
    moe_scan = cfg.is_moe
    params['blocks'] = [
        _stack_init(jax.random.fold_in(ks[3], j), G,
                    functools.partial(_init_layer, cfg=cfg,
                                      kind=kinds[n_prefix + j],
                                      moe_layer=moe_scan and _is_moe_layer(
                                          cfg, n_prefix + j),
                                      cross=cross, dtype=dtype))
        for j in range(P)] if G else []
    tail_base = n_prefix + G * P
    params['tail'] = [
        _init_layer(jax.random.fold_in(ks[4], i), cfg, kinds[tail_base + i],
                    moe_layer=_is_moe_layer(cfg, tail_base + i), cross=cross,
                    dtype=dtype)
        for i in range(R)]
    if cfg.arch_kind == 'encdec':
        enc_keys = jax.random.split(ks[5], cfg.num_encoder_layers)
        params['encoder'] = {
            'layers': [_init_layer(k, cfg, 'encoder', moe_layer=False,
                                   dtype=dtype) for k in enc_keys],
            'final_norm': init_norm(cfg.d_model, dtype)}
    return params


# ------------------------------------------------------------- layer forward


def _ffn(lp, h, cfg, quant):
    if 'moe' in lp:
        return moe_lib.moe_block(lp['moe'], h, cfg, quant=quant)
    return mlp(lp['mlp'], h, quant=quant)


def layer_forward(lp, x, kind, cfg, *, positions, quant, enc=None,
                  enc_pos=None, want_cache=False):
    """Full-sequence layer. Returns (x, cache_entries | None)."""
    h = rms_norm(lp['norm1'], x, cfg.norm_eps)
    kvs = None
    if kind == 'ssm':
        return x + rec.mamba2_forward(lp['mamba'], h, cfg, quant=quant), None
    if kind == 'recurrent':
        x = x + rec.rglru_forward(lp['rglru'], h, cfg, quant=quant)
    elif cfg.use_mla:
        o, kvs = attn.mla_forward(lp['attn'], h, positions, cfg, quant=quant)
        x = x + o
    else:
        o, kvs = attn.gqa_forward(lp['attn'], h, positions, cfg, kind=kind,
                                  quant=quant)
        x = x + o
    if 'xattn' in lp:
        hx = rms_norm(lp['norm_x'], x, cfg.norm_eps)
        o, _ = attn.gqa_forward(lp['xattn'], hx, positions, cfg, kind='cross',
                                quant=quant, kv=(enc, enc_pos))
        x = x + o
    x = x + _ffn(lp, rms_norm(lp['norm2'], x, cfg.norm_eps), cfg, quant)
    return x, (kvs if want_cache else None)


def layer_decode(lp, x, kind, cfg, *, cur, cache, ctx, quant, enc=None,
                 enc_pos=None):
    """One-token layer step. x: (B, d). Returns (x, new_cache)."""
    h = rms_norm(lp['norm1'], x, cfg.norm_eps)
    if kind == 'ssm':
        o, c = rec.mamba2_decode(lp['mamba'], h, cache, cfg, quant=quant)
        return x + o, c
    if kind == 'recurrent':
        o, c = rec.rglru_decode(lp['rglru'], h, cache, cfg, quant=quant)
        x = x + o
    elif cfg.use_mla:
        o, c = attn.mla_decode(lp['attn'], h, cur, cfg, cache=cache, ctx=ctx,
                               quant=quant)
        x = x + o
    else:
        o, c = attn.gqa_decode(lp['attn'], h, cur, cfg, kind=kind, cache=cache,
                               ctx=ctx, quant=quant)
        x = x + o
    if 'xattn' in lp:
        hx = rms_norm(lp['norm_x'], x, cfg.norm_eps)
        x = x + attn.gqa_cross_decode(lp['xattn'], hx, enc, enc_pos, cfg,
                                      quant=quant)
    x = x + _ffn(lp, rms_norm(lp['norm2'], x[:, None], cfg.norm_eps), cfg,
                 quant)[:, 0]
    return x, c


# ------------------------------------------------------------ cache builders


def init_layer_cache(cfg, kind, batch, max_len, dtype):
    if kind == 'ssm':
        return rec.init_mamba2_cache(cfg, batch, dtype)
    if kind == 'recurrent':
        return rec.init_rglru_cache(cfg, batch, dtype)
    if cfg.use_mla:
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    return attn.init_attn_cache(cfg, batch, kind, max_len, dtype)


def init_cache(cfg: ModelConfig, batch, max_len):
    dtype = jnp.dtype(cfg.dtype)
    n_prefix, G, P, R = layer_groups(cfg)
    kinds = cfg.layer_kinds()

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), one)

    tail_base = n_prefix + G * P
    return {
        'prefix': [init_layer_cache(cfg, kinds[i], batch, max_len, dtype)
                   for i in range(n_prefix)],
        'blocks': [stacked(kinds[n_prefix + j]) for j in range(P)] if G else [],
        'tail': [init_layer_cache(cfg, kinds[tail_base + i], batch, max_len,
                                  dtype) for i in range(R)],
    }


def _fill_cache(cfg, kind, cache, kvs, positions, state=None):
    """Insert prefill outputs into an empty cache entry."""
    if kind == 'ssm':
        return {'h': state, 'conv': cache['conv']}    # conv tail ~0 init ok
    if kind == 'recurrent':
        return kvs                                    # rglru returns state dict
    if cfg.use_mla:
        return attn.prefill_mla_cache_write(cache, kvs[0], kvs[1], positions)
    return attn.prefill_cache_write(cache, kvs[0], kvs[1], positions)


# ------------------------------------------------------------------ forward


def encode(params, cfg, frames):
    """Whisper-style encoder over stubbed frame embeddings (B, F, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    quant = (cfg.w_bits, cfg.a_bits)
    for lp in params['encoder']['layers']:
        x, _ = layer_forward(lp, x, 'encoder', cfg, positions=pos, quant=quant)
    return rms_norm(params['encoder']['final_norm'], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, embeds=None, enc=None,
            enc_pos=None, remat=False, collect_hiddens=False):
    """Training/eval forward → logits (B, S, vocab).

    ``embeds``: optional frontend embeddings (B, F, d) prepended (VLM) —
    logits are returned for the full concatenated sequence.
    ``collect_hiddens``: also return per-scan-group hidden states for
    early-exit heads (used by the compression chain at small scale).
    """
    dtype = jnp.dtype(cfg.dtype)
    quant = (cfg.w_bits, cfg.a_bits)
    x = shard_act(embed(params['embed'], tokens, dtype))
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    n_prefix, G, P, R = layer_groups(cfg)
    kinds = cfg.layer_kinds()

    def apply_one(lp, x, kind):
        y, _ = layer_forward(lp, x, kind, cfg, positions=positions,
                             quant=quant, enc=enc, enc_pos=enc_pos)
        return shard_act(y)

    for i, lp in enumerate(params['prefix']):
        x = apply_one(lp, x, kinds[i])

    hiddens = []
    if G:
        scan_kinds = tuple(kinds[n_prefix + j] for j in range(P))

        def body(x, slices):
            for lp, kind in zip(slices, scan_kinds):
                x = apply_one(lp, x, kind)
            return x, (x if collect_hiddens else None)

        if remat:
            body = jax.checkpoint(body)
        x, hs = jax.lax.scan(body, x, tuple(params['blocks']))
        if collect_hiddens:
            hiddens = hs                               # (G, B, S, d)

    tail_base = n_prefix + G * P
    for i, lp in enumerate(params['tail']):
        x = apply_one(lp, x, kinds[tail_base + i])

    x = rms_norm(params['final_norm'], x, cfg.norm_eps)
    logits = unembed(params.get('unembed', params['embed']), x, quant=quant)
    logits = shard_act(softcap(logits, cfg.logit_softcap), 'logits')
    if collect_hiddens:
        return logits, hiddens
    return logits


def prefill(params, cfg: ModelConfig, tokens, *, embeds=None, enc=None,
            enc_pos=None, max_len=None):
    """Forward + cache build. Returns (last_logits (B, vocab), cache)."""
    dtype = jnp.dtype(cfg.dtype)
    quant = (cfg.w_bits, cfg.a_bits)
    x = shard_act(embed(params['embed'], tokens, dtype))
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dtype), x], axis=1)
    B, S = x.shape[:2]
    max_len = max_len or cfg.max_seq_len
    positions = jnp.arange(S, dtype=jnp.int32)
    n_prefix, G, P, R = layer_groups(cfg)
    kinds = cfg.layer_kinds()
    cache = init_cache(cfg, B, max_len)

    def run(lp, x, kind, centry):
        if kind == 'ssm':
            h = rms_norm(lp['norm1'], x, cfg.norm_eps)
            o, (st, conv_tail) = rec.mamba2_forward(lp['mamba'], h, cfg,
                                                    quant=quant,
                                                    return_state=True)
            return x + o, {'h': st, 'conv': conv_tail.astype(
                centry['conv'].dtype)}
        if kind == 'recurrent':
            h = rms_norm(lp['norm1'], x, cfg.norm_eps)
            # rerun recurrence capturing final state via forward + manual state
            a, b = rec._rglru_gates(
                lp['rglru'],
                rec.causal_conv1d(lp['rglru']['conv'],
                                  dense(lp['rglru']['wx'], h, quant=quant)),
                quant)
            gate = jax.nn.gelu(dense(lp['rglru']['wgate'], h, quant=quant))

            def comb(l, r):
                (al, bl), (ar, br) = l, r
                return al * ar, ar * bl + br
            _, hseq = jax.lax.associative_scan(comb, (a, b), axis=1)
            o = dense(lp['rglru']['wo'], hseq.astype(x.dtype) * gate,
                      quant=quant)
            x = x + o
            conv_in = dense(lp['rglru']['wx'], h, quant=quant)
            k = cfg.rglru_conv
            st = {'h': hseq[:, -1], 'conv': conv_in[:, -(k - 1):, :]}
            x = x + _ffn(lp, rms_norm(lp['norm2'], x, cfg.norm_eps), cfg, quant)
            return x, st
        y, kvs = layer_forward(lp, x, kind, cfg, positions=positions,
                               quant=quant, enc=enc, enc_pos=enc_pos,
                               want_cache=True)
        return shard_act(y), _fill_cache(cfg, kind, centry, kvs, positions)

    for i, lp in enumerate(params['prefix']):
        x, cache['prefix'][i] = run(lp, x, kinds[i], cache['prefix'][i])

    if G:
        scan_kinds = tuple(kinds[n_prefix + j] for j in range(P))

        def body(x, xs):
            slices, centries = xs
            new = []
            for lp, kind, ce in zip(slices, scan_kinds, centries):
                x, c = run(lp, x, kind, ce)
                new.append(c)
            return x, tuple(new)

        x, newc = jax.lax.scan(body, x, (tuple(params['blocks']),
                                         tuple(cache['blocks'])))
        cache['blocks'] = list(newc)

    tail_base = n_prefix + G * P
    for i, lp in enumerate(params['tail']):
        x, cache['tail'][i] = run(lp, x, kinds[tail_base + i],
                                  cache['tail'][i])

    x = rms_norm(params['final_norm'], x[:, -1:], cfg.norm_eps)
    logits = unembed(params.get('unembed', params['embed']), x, quant=quant)
    return softcap(logits[:, 0], cfg.logit_softcap), cache


def decode_step(params, cfg: ModelConfig, token, cur, cache, *, ctx=None,
                enc=None, enc_pos=None):
    """One decode step. token: (B,) int32; cur: scalar int32 position.

    Returns (logits (B, vocab), new_cache).
    """
    ctx = ctx or {}
    dtype = jnp.dtype(cfg.dtype)
    quant = (cfg.w_bits, cfg.a_bits)
    x = shard_act(embed(params['embed'], token, dtype), 'residual1')
    n_prefix, G, P, R = layer_groups(cfg)
    kinds = cfg.layer_kinds()

    def run(lp, x, kind, centry):
        y, c = layer_decode(lp, x, kind, cfg, cur=cur, cache=centry, ctx=ctx,
                            quant=quant, enc=enc, enc_pos=enc_pos)
        return shard_act(y, 'residual1'), c

    new_cache = {'prefix': [], 'blocks': [], 'tail': []}
    for i, lp in enumerate(params['prefix']):
        x, c = run(lp, x, kinds[i], cache['prefix'][i])
        new_cache['prefix'].append(c)

    if G:
        scan_kinds = tuple(kinds[n_prefix + j] for j in range(P))

        def body(x, xs):
            slices, centries = xs
            cs = []
            for lp, kind, ce in zip(slices, scan_kinds, centries):
                x, c = run(lp, x, kind, ce)
                cs.append(c)
            return x, tuple(cs)

        x, newc = jax.lax.scan(body, x, (tuple(params['blocks']),
                                         tuple(cache['blocks'])))
        new_cache['blocks'] = list(newc)

    tail_base = n_prefix + G * P
    for i, lp in enumerate(params['tail']):
        x, c = run(lp, x, kinds[tail_base + i], cache['tail'][i])
        new_cache['tail'].append(c)

    x = rms_norm(params['final_norm'], x, cfg.norm_eps)
    logits = unembed(params.get('unembed', params['embed']), x, quant=quant)
    return softcap(logits, cfg.logit_softcap), new_cache
