"""Core functional layers (pure JAX, params as pytrees of jnp arrays).

Every matmul routes through :func:`dense`, which applies the paper's
fixed-point fake-quantization when ``quant=(w_bits, a_bits)`` is set — this
is the single integration point of the Q pass with every architecture.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quantization import fake_quant_act, fake_quant_weight

# --------------------------------------------------------------------------- init


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(max(fan_in, 1)))


def init_dense(key, d_in, d_out, *, bias=False, dtype=jnp.float32):
    p = {'w': he_init(key, (d_in, d_out), d_in, dtype)}
    if bias:
        p['b'] = jnp.zeros((d_out,), dtype)
    return p


def init_norm(d, dtype=jnp.float32):
    return {'scale': jnp.ones((d,), dtype)}


# ------------------------------------------------------------------------- apply


def dense(p, x, *, quant=(0, 0)):
    """x @ w (+b), with optional fake-quant of weight (per out-channel) and act.

    Also accepts the int8 serving form {'w_q': int8, 'scale': (out,)} from
    core.quantization.quantize_params_for_serving — weights stream from HBM
    as int8 and dequantize in-register (Pallas quant_matmul on TPU) — and
    the low-rank factored form {'u', 'v'} from core/family.py factorize
    (two chained matmuls; composes with either weight representation).
    """
    if 'u' in p and 'v' in p:
        return dense(p['v'], dense(p['u'], x, quant=quant), quant=quant)
    w_bits, a_bits = quant
    if 'w_q' in p:
        w = p['w_q'].astype(x.dtype) * p['scale'].astype(x.dtype)
        if a_bits:
            x = fake_quant_act(x, a_bits)
        y = jnp.einsum('...d,df->...f', x, w)
        if 'b' in p:
            y = y + p['b'].astype(x.dtype)
        return y
    w = p['w']
    if w_bits:
        w = fake_quant_weight(w, w_bits, axis=-1)
    if a_bits:
        x = fake_quant_act(x, a_bits)
    y = jnp.einsum('...d,df->...f', x, w.astype(x.dtype))
    if 'b' in p:
        y = y + p['b'].astype(x.dtype)
    return y


def rms_norm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p['scale'].astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------- rope


def rope(x, positions, *, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, D) or (..., H, D) with matching positions."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    # broadcast over head axis: x is (..., S, H, D), ang (..., S, half)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------- mlp


def init_mlp(key, cfg, d_ff=None, *, gated=True, dtype=jnp.float32):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if gated:
        return {'wi': init_dense(ks[0], d, f, dtype=dtype),
                'wg': init_dense(ks[1], d, f, dtype=dtype),
                'wo': init_dense(ks[2], f, d, dtype=dtype)}
    return {'wi': init_dense(ks[0], d, f, dtype=dtype),
            'wo': init_dense(ks[2], f, d, dtype=dtype)}


def mlp(p, x, *, quant=(0, 0)):
    if 'wg' in p:  # gated (swiglu)
        h = jax.nn.silu(dense(p['wg'], x, quant=quant)) * dense(p['wi'], x, quant=quant)
    else:
        h = jax.nn.gelu(dense(p['wi'], x, quant=quant))
    return dense(p['wo'], h, quant=quant)


# ---------------------------------------------------------------------- embedding


def init_embedding(key, vocab, d, dtype=jnp.float32):
    return {'table': jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p, tokens, dtype):
    return jnp.take(p['table'], tokens, axis=0).astype(dtype)


def unembed(p, x, *, quant=(0, 0)):
    w = p['table']
    if quant[0]:
        w = fake_quant_weight(w, quant[0], axis=0)
    if quant[1]:
        x = fake_quant_act(x, quant[1])
    return jnp.einsum('...d,vd->...v', x, w.astype(x.dtype))


# ------------------------------------------------------------- causal depthwise conv


def init_conv1d(key, width, k, dtype=jnp.float32):
    return {'w': he_init(key, (k, width), k, dtype), 'b': jnp.zeros((width,), dtype)}


def causal_conv1d(p, x):
    """Depthwise causal conv. x: (B, S, C) -> (B, S, C)."""
    k = p['w'].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # (B, S+k-1, C) -> windows: use conv_general_dilated depthwise
    y = jax.lax.conv_general_dilated(
        xp.transpose(0, 2, 1)[:, :, None, :],                   # (B, C, 1, S+k-1)
        p['w'].T[:, None, None, :],                             # (C, 1, 1, k)
        window_strides=(1, 1), padding='VALID',
        feature_group_count=x.shape[-1],
        dimension_numbers=('NCHW', 'OIHW', 'NCHW'))
    y = y[:, :, 0, :].transpose(0, 2, 1)
    return y + p['b'].astype(y.dtype)


def conv1d_step(p, x_t, conv_state):
    """One decode step of the causal depthwise conv.

    x_t: (B, C); conv_state: (B, k-1, C) past inputs. Returns (y_t, new_state).
    """
    k = p['w'].shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,k,C)
    y = jnp.einsum('bkc,kc->bc', window, p['w'].astype(x_t.dtype))
    y = y + p['b'].astype(y.dtype)
    new_state = window[:, 1:k, :]
    return y, new_state
