"""Mixture-of-Experts block: sort-based grouped matmul with fixed capacity.

TPU-native formulation: tokens are argsorted by expert id and packed into a
dense (E, C, D) buffer (C = per-expert capacity, overflow dropped as in
standard capacity-factor MoE), experts run as one batched einsum with the
expert axis sharded over 'model' (expert parallelism), and results scatter
back with gate weights.  Memory is O(tokens·top_k·D) — no (T,E,C) dispatch
one-hots — which is what lets deepseek-v3's 256-expert layers lower at 1M
tokens/step.

Expert pruning (the paper's P pass at expert granularity) simply shrinks the
leading E dim of the stacked expert weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import fake_quant_act, fake_quant_weight
from repro.models.actsharding import shard_act
from repro.models.layers import he_init, init_dense, dense


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        'router': init_dense(ks[0], d, E, dtype=dtype),
        'wi': he_init(ks[1], (E, d, f), d, dtype),
        'wg': he_init(ks[2], (E, d, f), d, dtype),
        'wo': he_init(ks[3], (E, f, d), f, dtype),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import init_mlp
        p['shared'] = init_mlp(ks[4], cfg,
                               cfg.moe_d_ff * cfg.n_shared_experts, dtype=dtype)
    return p


def _maybe_quant_w(w, bits):
    if isinstance(w, dict):                 # int8 serving form
        return w['w_q'].astype(jnp.float32) * w['scale']
    return fake_quant_weight(w, bits, axis=-1) if bits else w


def moe_block(p, x, cfg, *, quant=(0, 0)):
    """x: (B, S, D) -> (B, S, D); top-k routed experts + optional shared.

    On a mesh (launcher-installed policy) this dispatches to the
    shard_map expert-parallel path: local sort + TP-partial expert matmuls
    + one psum — replacing the global scatter whose partial-sum all-reduce
    moved the full (E, C, D) dispatch buffer per layer (§Perf iteration 2).
    """
    import os
    from repro.models.actsharding import current_mesh
    mesh = current_mesh()
    # REPRO_MOE_MODE=dense forces the naive global-scatter path (the
    # paper-faithful-framework baseline measured in §Perf before the EP
    # iterations).
    if os.environ.get('REPRO_MOE_MODE', 'auto') != 'dense' \
            and mesh is not None and x.ndim == 3:
        dp = 1
        for a in mesh.axis_names:
            if a != 'model':
                dp *= mesh.shape[a]
        if x.shape[0] % dp == 0 and not isinstance(p['wi'], dict):
            return _moe_block_ep(p, x, cfg, mesh, quant=quant)
    return _moe_block_dense(p, x, cfg, quant=quant)


def _moe_block_dense(p, x, cfg, *, quant=(0, 0)):
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    logits = dense(p['router'], xf.astype(jnp.float32))          # (T, E)
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    cap = int(max(1, round(T * k / E * cfg.capacity_factor)))
    eid = eidx.reshape(T * k)
    order = jnp.argsort(eid)                                     # stable
    sorted_eid = eid[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_eid]
    keep = pos_in_e < cap
    dst = jnp.where(keep, sorted_eid * cap + pos_in_e, E * cap)  # overflow slot
    src_tok = order // k                                         # token per assignment

    import os
    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    buf = buf.at[dst].set(xf[src_tok])
    buf = buf[:-1].reshape(E, cap, D)
    if os.environ.get('REPRO_MOE_MODE', 'auto') != 'dense':
        # anchor the grouped-matmul layout: experts over 'model' when
        # divisible, else capacity over the whole mesh — without this GSPMD
        # replicates the expert compute when E < model-axis (16x excess
        # FLOPs, §Perf iteration 1).
        buf = shard_act(buf, 'moe_buf')

    w_bits, a_bits = quant
    if a_bits:
        buf = fake_quant_act(buf, a_bits)
    wg = _maybe_quant_w(p['wg'], w_bits).astype(x.dtype)
    wi = _maybe_quant_w(p['wi'], w_bits).astype(x.dtype)
    wo = _maybe_quant_w(p['wo'], w_bits).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum('ecd,edf->ecf', buf, wg)) \
        * jnp.einsum('ecd,edf->ecf', buf, wi)
    if a_bits:
        h = fake_quant_act(h, a_bits)
    out_buf = jnp.einsum('ecf,efd->ecd', h, wo)                  # (E, cap, D)

    flat = jnp.concatenate([out_buf.reshape(E * cap, D),
                            jnp.zeros((1, D), x.dtype)], axis=0)
    gathered = flat[dst] * (gates.reshape(T * k)[order] * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[src_tok].add(gathered)

    if 'shared' in p:
        from repro.models.layers import mlp
        y = y + mlp(p['shared'], xf, quant=quant)
    return y.reshape(B, S, D)


def _dispatch_local(xf, logits, E, k, cf):
    """Sort-based dispatch of LOCAL tokens into a (E, C_l, D) buffer.

    Returns (buf, dst, src_tok, gate_keep) for combine.  Uses scatter-add
    with masked values (no overflow row), so the buffer shape is exactly
    (E*C_l, D) and shards cleanly.
    """
    T, D = xf.shape
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    cap = int(max(1, round(T * k / E * cf)))
    eid = eidx.reshape(T * k)
    order = jnp.argsort(eid)
    sorted_eid = eid[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_eid]
    keep = pos_in_e < cap
    dst = jnp.where(keep, sorted_eid * cap + pos_in_e, 0)
    src_tok = order // k
    buf = jnp.zeros((E * cap, D), xf.dtype)
    buf = buf.at[dst].add(xf[src_tok] * keep[:, None].astype(xf.dtype))
    gate_keep = (gates.reshape(T * k)[order] * keep).astype(xf.dtype)
    return buf.reshape(E, cap, D), dst, src_tok, gate_keep


def _moe_block_ep(p, x, cfg, mesh, *, quant=(0, 0)):
    """Expert-parallel MoE under shard_map.  Two modes:

    * a2a mode (E % model == 0, deepseek-v3): experts sharded over 'model';
      local dispatch -> all_to_all(E -> capacity) -> fully-local expert FFN
      -> reverse all_to_all -> local combine.  Wire cost: 2 all_to_alls of
      the (T_local·k, D) activations — the textbook EP schedule.
    * f-TP mode (E < model, mixtral): experts replicated, FFN hidden dim
      tensor-parallel over 'model'; one psum of the combined (T_local, D)
      output — the same wire cost as a dense Megatron MLP layer.

    Both replace the unsharded global scatter whose partial-sum all-reduce
    moved the full (E, C, D) buffer per layer (§Perf iteration 2).
    """
    from repro.launch.serving import shard_map          # version shim
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != 'model')
    dps = dp if len(dp) > 1 else dp[0]
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    m = mesh.shape['model']
    w_bits, a_bits = quant
    # a2a mode needs token parallelism on 'model' too (sequence-sharded
    # dispatch) — otherwise every model column dispatches the same tokens
    # (m-fold redundant compute, observed in §Perf iteration 3).
    a2a = E % m == 0 and S % m == 0 and S > 1

    def body(x, router_w, wi, wg, wo):
        Bl, Sl, D = x.shape
        xf = x.reshape(Bl * Sl, D)
        logits = jnp.einsum('td,de->te', xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        buf, dst, src_tok, gk = _dispatch_local(xf, logits, E, k,
                                                cfg.capacity_factor)
        if a2a:                                        # (E, C, D)->(E/m, C*m, D)
            buf = jax.lax.all_to_all(buf, 'model', split_axis=0,
                                     concat_axis=1, tiled=True)
        if a_bits:
            buf = fake_quant_act(buf, a_bits)
        wi_, wg_, wo_ = (_maybe_quant_w(w, w_bits).astype(x.dtype)
                         for w in (wi, wg, wo))
        h = jax.nn.silu(jnp.einsum('ecd,edf->ecf', buf, wg_)) \
            * jnp.einsum('ecd,edf->ecf', buf, wi_)
        if a_bits:
            h = fake_quant_act(h, a_bits)
        out_buf = jnp.einsum('ecf,efd->ecd', h, wo_)
        if a2a:                                        # back to (E, C, D)
            out_buf = jax.lax.all_to_all(out_buf, 'model', split_axis=1,
                                         concat_axis=0, tiled=True)
        cap = out_buf.shape[1]
        flat = out_buf.reshape(E * cap, D)
        y = jnp.zeros((Bl * Sl, D), x.dtype).at[src_tok].add(
            flat[dst] * gk[:, None])
        if not a2a:
            y = jax.lax.psum(y, 'model')               # f-TP partial sums
        return y.reshape(Bl, Sl, D)

    ew = (P('model', None, None) if a2a else P(None, None, 'model'))
    ewo = (P('model', None, None) if a2a else P(None, 'model', None))
    xspec = P(dps, 'model', None) if a2a else P(dps, None, None)
    fn = shard_map(body, mesh,
                   in_specs=(xspec, P(None, None), ew, ew, ewo),
                   out_specs=xspec)
    y = fn(x, p['router']['w'], p['wi'], p['wg'], p['wo'])
    if 'shared' in p:                    # shared expert: plain TP dense MLP
        from repro.models.layers import mlp
        y = y + mlp(p['shared'], x, quant=quant)
    return y


def moe_aux_loss(p, x, cfg):
    """Load-balancing auxiliary loss (Switch-style f·P)."""
    B, S, D = x.shape
    logits = dense(p['router'], x.reshape(-1, D).astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(probs, cfg.top_k)
    f = jnp.mean(jax.nn.one_hot(eidx, cfg.n_experts).sum(1), axis=0)
    P = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * P)
