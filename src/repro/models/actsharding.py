"""Activation-sharding policy hook.

Model code calls ``shard_act(x, kind)`` at layer boundaries; by default it
is a no-op (CPU tests, single device).  The launcher installs a policy that
applies ``jax.lax.with_sharding_constraint`` — batch over the DP axes on the
residual stream — which anchors GSPMD's propagation so FSDP'd weights are
all-gathered per layer instead of activations being replicated (the
catastrophic inversion the dry-run exposed for unconstrained graphs).
"""
from __future__ import annotations

import contextlib
from typing import Callable

_POLICY: Callable | None = None
_MESH = None


def set_policy(fn: Callable | None, mesh=None):
    global _POLICY, _MESH
    _POLICY = fn
    _MESH = mesh


@contextlib.contextmanager
def activation_sharding(fn: Callable, mesh=None):
    global _POLICY, _MESH
    prev, prev_mesh = _POLICY, _MESH
    _POLICY, _MESH = fn, mesh if mesh is not None else getattr(
        fn, 'mesh', None)
    try:
        yield
    finally:
        _POLICY, _MESH = prev, prev_mesh


def shard_act(x, kind: str = 'residual'):
    if _POLICY is None:
        return x
    return _POLICY(x, kind)


def current_mesh():
    """Mesh installed with the active policy (None on single device)."""
    return _MESH


def make_mesh_policy(mesh):
    """Standard policy: batch dim over DP axes, features unsharded (TP on
    features emerges from the weight shardings); vocab-sharded logits."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != 'model')
    dps = dp if len(dp) > 1 else dp[0]

    def policy(x, kind):
        if kind == 'residual':                       # (B, S, D)
            if x.ndim == 3 and x.shape[0] % _size(mesh, dp) == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dps, None, None)))
            return x
        if kind == 'residual1':                      # (B, D) decode
            if x.shape[0] % _size(mesh, dp) == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dps, None)))
            return x
        if kind == 'moe_buf':                        # (E, C, D) dispatch buf
            E, C = x.shape[0], x.shape[1]
            m = mesh.shape['model']
            if E % m == 0 and C % _size(mesh, dp) == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P('model', dps, None)))
            full = dp + ('model',)
            if C % _size(mesh, full) == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, full, None)))
            if C % m == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, 'model', None)))
            return x
        if kind == 'logits':                         # (..., vocab)
            spec = (dps,) + (None,) * (x.ndim - 2) + ('model',)
            if x.shape[0] % _size(mesh, dp) == 0 \
                    and x.shape[-1] % mesh.shape['model'] == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(*spec)))
            return x
        return x

    policy.mesh = mesh
    return policy


def _size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
