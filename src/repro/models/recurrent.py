"""Recurrent blocks: RG-LRU (recurrentgemma/Griffin) and Mamba-2 SSD.

Both are written TPU-natively: training/prefill uses chunked/associative
scans (log-depth on the sequence axis, matmul-heavy inner terms for the
MXU); decode carries O(1) state — which is why these archs run the
``long_500k`` cell natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (causal_conv1d, conv1d_step, dense, he_init,
                                 init_conv1d, init_dense, rms_norm)

# ============================================================== RG-LRU (Griffin)

_RGLRU_C = 8.0


def init_rglru(key, cfg, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 6)
    return {
        'wgate': init_dense(ks[0], d, w, dtype=dtype),
        'wx': init_dense(ks[1], d, w, dtype=dtype),
        'conv': init_conv1d(ks[2], w, cfg.rglru_conv, dtype),
        'w_r': init_dense(ks[3], w, w, dtype=dtype),
        'w_i': init_dense(ks[4], w, w, dtype=dtype),
        'lam': jnp.full((w,), 2.0, dtype),      # softplus(2) ~ healthy decay
        'wo': init_dense(ks[5], w, d, dtype=dtype),
    }


def _rglru_gates(p, u, quant):
    r = jax.nn.sigmoid(dense(p['w_r'], u, quant=quant).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p['w_i'], u, quant=quant).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p['lam'].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * u.astype(jnp.float32)
    return a, b


def rglru_forward(p, x, cfg, *, quant=(0, 0)):
    """x: (B,S,D) -> (B,S,D). Linear recurrence via associative scan."""
    gate = jax.nn.gelu(dense(p['wgate'], x, quant=quant))
    u = causal_conv1d(p['conv'], dense(p['wx'], x, quant=quant))
    a, b = _rglru_gates(p, u, quant)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return dense(p['wo'], h * gate, quant=quant)


def rglru_decode(p, x, cache, cfg, *, quant=(0, 0)):
    """x: (B,D); cache = {'h': (B,W) fp32, 'conv': (B,k-1,W)}."""
    gate = jax.nn.gelu(dense(p['wgate'], x, quant=quant))
    u0 = dense(p['wx'], x, quant=quant)
    u, conv_state = conv1d_step(p['conv'], u0, cache['conv'])
    a, b = _rglru_gates(p, u, quant)
    h = a * cache['h'] + b
    out = dense(p['wo'], h.astype(x.dtype) * gate, quant=quant)
    return out, {'h': h, 'conv': conv_state}


def init_rglru_cache(cfg, batch, dtype):
    w = cfg.rglru_width
    return {'h': jnp.zeros((batch, w), jnp.float32),
            'conv': jnp.zeros((batch, cfg.rglru_conv - 1, w), dtype)}


# ================================================================= Mamba-2 (SSD)


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n, hd = cfg.ssm_state, cfg.ssm_headdim
    h = d_in // hd
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * n
    return {
        'in_proj': init_dense(ks[0], d, 2 * d_in + 2 * n + h, dtype=dtype),
        'conv': init_conv1d(ks[1], conv_ch, cfg.ssm_conv, dtype),
        'A_log': jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        'D': jnp.ones((h,), jnp.float32),
        'dt_bias': jnp.zeros((h,), jnp.float32),
        'norm': {'scale': jnp.ones((d_in,), dtype)},
        'out_proj': init_dense(ks[2], d_in, d, dtype=dtype),
    }


def _split_inproj(cfg, zxbcdt):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = d_in // cfg.ssm_headdim
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xBC, dt


def ssd_chunked(x, a, B, C, chunk):
    """Chunked SSD scan (state-space duality, mamba2 minimal formulation).

    x: (b,l,h,p)  a: (b,l,h) log-decay per step  B,C: (b,l,n) (ngroups=1).
    Returns y (b,l,h,p) and final state (b,h,p,n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, l)
    assert l % L == 0, f'seq {l} not divisible by ssm chunk {L}'
    c = l // L
    xr = x.reshape(b, c, L, h, p)
    ar = a.reshape(b, c, L, h)
    Br = B.reshape(b, c, L, n)
    Cr = C.reshape(b, c, L, n)

    a_cs = jnp.cumsum(ar, axis=2)                                # (b,c,L,h)
    # --- intra-chunk (quadratic in L, matmul-shaped for the MXU)
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]        # (b,c,L,S,h)
    causal = jnp.tril(jnp.ones((L, L), bool))
    att = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum('bcln,bcsn->bcls', Cr, Br)                   # (b,c,L,S)
    y_diag = jnp.einsum('bcls,bclsh,bcshp->bclhp', cb, att, xr.astype(jnp.float32))

    # --- per-chunk end states
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)            # (b,c,L,h)
    states = jnp.einsum('bcln,bclh,bclhp->bchpn', Br, decay_states,
                        xr.astype(jnp.float32))

    # --- inter-chunk linear recurrence over c (associative scan)
    a_tot = jnp.exp(a_cs[:, :, -1, :])                           # (b,c,h)

    def combine(lhs, rhs):
        (al, sl), (ar_, sr) = lhs, rhs
        return al * ar_, ar_[..., None, None] * sl + sr

    a_run, s_run = jax.lax.associative_scan(combine, (a_tot, states), axis=1)
    # state entering chunk i = state after chunk i-1
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_run[:, :1]), s_run[:, :-1]], axis=1)   # (b,c,h,p,n)

    y_off = jnp.einsum('bcln,bchpn,bclh->bclhp', Cr, s_prev,
                       jnp.exp(a_cs))
    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, s_run[:, -1]


def mamba2_forward(p, x, cfg, *, quant=(0, 0), return_state=False):
    """x: (B,S,D) -> (B,S,D)."""
    Bsz, S, D = x.shape
    d_in = cfg.ssm_expand * D
    n, hd = cfg.ssm_state, cfg.ssm_headdim
    h = d_in // hd
    z, xBC_raw, dt_raw = _split_inproj(cfg, dense(p['in_proj'], x, quant=quant))
    xBC = jax.nn.silu(causal_conv1d(p['conv'], xBC_raw))
    xs, B, C = jnp.split(xBC, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p['dt_bias'])  # (B,S,h)
    A = -jnp.exp(p['A_log'])
    a = dt * A                                                    # log decay
    xh = xs.reshape(Bsz, S, h, hd)
    xd = xh * dt[..., None].astype(xs.dtype)
    L = min(cfg.ssm_chunk, S)
    pad = (-S) % L
    if pad:
        # zero-pad: a=0 (decay 1) and x/B/C=0 leave y[:S] and the final
        # state exactly unchanged.
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(xd, a, B, C, cfg.ssm_chunk)
    y = y[:, :S]
    y = y + p['D'].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, S, d_in)
    y = rms_norm(p['norm'], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p['out_proj'], y, quant=quant)
    if return_state:
        # conv tail so decode can continue seamlessly after prefill
        conv_tail = xBC_raw[:, -(cfg.ssm_conv - 1):, :]
        return out, (state, conv_tail)
    return out


def mamba2_decode(p, x, cache, cfg, *, quant=(0, 0)):
    """x: (B,D); cache = {'h': (B,h,p,n) fp32, 'conv': (B,k-1,conv_ch)}."""
    Bsz, D = x.shape
    d_in = cfg.ssm_expand * D
    n, hd = cfg.ssm_state, cfg.ssm_headdim
    h = d_in // hd
    z, xBC0, dt_raw = _split_inproj(cfg, dense(p['in_proj'], x, quant=quant))
    xBC, conv_state = conv1d_step(p['conv'], xBC0, cache['conv'])
    xBC = jax.nn.silu(xBC)
    xs, B, C = jnp.split(xBC, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p['dt_bias'])  # (B,h)
    A = -jnp.exp(p['A_log'])
    xh = xs.reshape(Bsz, h, hd).astype(jnp.float32)
    hst = cache['h'] * jnp.exp(dt * A)[..., None, None] \
        + jnp.einsum('bh,bhp,bn->bhpn', dt, xh, B.astype(jnp.float32))
    y = jnp.einsum('bn,bhpn->bhp', C.astype(jnp.float32), hst)
    y = y + p['D'][None, :, None] * xh
    y = y.reshape(Bsz, d_in).astype(x.dtype)
    y = rms_norm(p['norm'], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p['out_proj'], y, quant=quant)
    return out, {'h': hst, 'conv': conv_state}


def init_mamba2_cache(cfg, batch, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = d_in // cfg.ssm_headdim
    return {'h': jnp.zeros((batch, h, cfg.ssm_headdim, n), jnp.float32),
            'conv': jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * n), dtype)}
