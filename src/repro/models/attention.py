"""Attention: GQA (global / sliding-window) and MLA (deepseek-v3).

Three execution modes:
  * train/prefill — chunked online-softmax attention (flash-style in pure
    JAX: O(S·chunk) logits memory instead of O(S²), which is what lets the
    32k-prefill cells fit in the dry-run memory analysis).
  * decode — one-token attention against a cache.  The cache is
    **sequence-sharded** across the 'model' axis in production; the decode
    attention is written as local-partials + softmax-merge so the launcher
    can wrap it in shard_map (``ctx['decode_attn']`` injection).  The default
    implementation here is the single-device reference of the same math.

Caches store an absolute-position vector ``pos`` (-1 = empty); sliding-window
layers allocate only ``window`` slots and write ring-buffer style
(slot = pos % window), which is what makes 500k-token decode of the
local-majority archs (gemma2/3, mixtral) memory-feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, he_init, init_dense, rope, softcap

NEG_INF = -1e30


# ----------------------------------------------------------------------- params


def init_attention(key, cfg, dtype=jnp.float32):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        'wq': init_dense(ks[0], d, H * hd, bias=bias, dtype=dtype),
        'wk': init_dense(ks[1], d, K * hd, bias=bias, dtype=dtype),
        'wv': init_dense(ks[2], d, K * hd, bias=bias, dtype=dtype),
        'wo': init_dense(ks[3], H * hd, d, dtype=dtype),
    }


def init_mla(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dr, dn, dv = cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        'wq_a': init_dense(ks[0], d, r_q, dtype=dtype),
        'q_norm': {'scale': jnp.ones((r_q,), dtype)},
        'wq_b': init_dense(ks[1], r_q, H * (dr + dn), dtype=dtype),
        'wkv_a': init_dense(ks[2], d, r_kv + dr, dtype=dtype),
        'kv_norm': {'scale': jnp.ones((r_kv,), dtype)},
        # up-projections from the latent, kept as per-head tensors so decode
        # can use the absorbed formulation.
        'wk_b': he_init(ks[3], (r_kv, H, dn), r_kv, dtype),
        'wv_b': he_init(ks[4], (r_kv, H, dv), r_kv, dtype),
        'wo': init_dense(ks[5], H * dv, d, dtype=dtype),
    }


# ------------------------------------------------- chunked attention (train/prefill)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                      attn_softcap=0.0, chunk=512):
    """Online-softmax attention over KV chunks.

    q: (B,S,H,Dq)  k: (B,T,K,Dq)  v: (B,T,K,Dv)  q_pos: (S,)  k_pos: (T,)
    Returns (B,S,H,Dv). GQA via H = K*g. k_pos == -1 marks padding.
    """
    B, S, H, Dq = q.shape
    T, K, Dv = k.shape[1], k.shape[2], v.shape[-1]
    g = H // K
    chunk = min(chunk, T)
    if T % chunk:
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
        T += pad
    nc = T // chunk
    qg = q.reshape(B, S, K, g, Dq) * (Dq ** -0.5)

    ks = k.reshape(B, nc, chunk, K, Dq).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, chunk, K, Dv).transpose(1, 0, 2, 3, 4)
    ps = k_pos.reshape(nc, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        logits = jnp.einsum('bskgd,bckd->bskgc', qg, kc.astype(qg.dtype),
                            preferred_element_type=jnp.float32)
        if attn_softcap:
            logits = softcap(logits, attn_softcap)
        valid = pc[None, :] >= 0
        if causal:
            valid &= pc[None, :] <= q_pos[:, None]
        if window:
            valid &= pc[None, :] > q_pos[:, None] - window
        logits = jnp.where(valid[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            'bskgc,bckv->bskgv', p.astype(vc.dtype), vc).astype(acc.dtype)
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, K, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, g), jnp.float32)
    a0 = jnp.zeros((B, S, K, g, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, Dv).astype(q.dtype)


# --------------------------------------------------------------- decode attention


def decode_attn_reference(q, new_k, new_v, cache, cur, *,
                          window=0, attn_softcap=0.0, axis_names=()):
    """One-token attention + cache write; local-partials + softmax-merge.

    q: (B,H,Dq); new_k/new_v: (B,K,Dq/Dv); cache: {'k','v','meta'[,scales]}
    with k (B,Sc,K,Dq) (int8 + 'k_s'/'v_s' scales when kv_cache_bits=8).
    When ``axis_names`` is non-empty this body runs inside shard_map with
    the cache sequence dim sharded over those axes: slot indices are then
    *local* (meta['slots'] carries the global offsets), and partial stats
    merge with pmax/psum.  Returns (out, new_cache).
    """
    cache_k, cache_v, meta = cache['k'], cache['v'], cache['meta']
    quantized = 'k_s' in cache
    B, Sc, K, Dq = cache_k.shape
    H = q.shape[1]
    g = H // K
    Dv = cache_v.shape[-1]

    # ring-buffer write: global slot = cur % n_slots; each device owns the
    # meta['slots'] range.  Single-slot dynamic-update-slice (in-place on
    # TPU) — a full-cache where() rewrite costs ~0.5 GB/layer/step at 32k
    # ctx (§Perf iteration 6).
    slot_ids = meta['slots']           # (Sc,) global slot indices owned here
    positions = meta['pos']            # (Sc,) absolute pos stored per slot
    write_slot = jnp.mod(cur, meta['total'])
    offset = slot_ids[0]
    loc = jnp.clip(write_slot - offset, 0, Sc - 1)
    owned = (write_slot >= offset) & (write_slot - offset < Sc)

    def wr(buf, new, axis=1):
        curslice = jax.lax.dynamic_slice_in_dim(buf, loc, 1, axis=axis)
        exp = jnp.expand_dims(new, axis)
        upd = jnp.where(owned, exp.astype(buf.dtype), curslice)
        return jax.lax.dynamic_update_slice_in_dim(buf, upd, loc, axis=axis)

    new_cache = dict(cache)
    if quantized:
        nk_q, nk_s = kv_quantize(new_k)
        nv_q, nv_s = kv_quantize(new_v)
        cache_k = wr(cache_k, nk_q)
        cache_v = wr(cache_v, nv_q)
        new_cache['k_s'] = wr(cache['k_s'], nk_s)
        new_cache['v_s'] = wr(cache['v_s'], nv_s)
        k_eff = kv_dequantize(cache_k, new_cache['k_s'], q.dtype)
        v_eff = kv_dequantize(cache_v, new_cache['v_s'], q.dtype)
    else:
        cache_k = wr(cache_k, new_k)
        cache_v = wr(cache_v, new_v)
        k_eff, v_eff = cache_k, cache_v
    pos_upd = jnp.where(owned, cur[None], jax.lax.dynamic_slice_in_dim(
        positions, loc, 1))
    positions = jax.lax.dynamic_update_slice_in_dim(positions, pos_upd,
                                                    loc, axis=0)
    new_cache['k'], new_cache['v'] = cache_k, cache_v
    new_cache['meta'] = dict(meta, pos=positions)

    qg = q.reshape(B, K, g, Dq) * (Dq ** -0.5)
    logits = jnp.einsum('bkgd,bskd->bkgs', qg, k_eff.astype(qg.dtype),
                        preferred_element_type=jnp.float32)
    if attn_softcap:
        logits = softcap(logits, attn_softcap)
    valid = (positions >= 0) & (positions <= cur)
    if window:
        valid &= positions > cur - window
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)

    m_loc = jnp.max(logits, axis=-1)
    m = m_loc
    for ax in axis_names:
        m = jax.lax.pmax(m, ax)
    m = jnp.maximum(m, -1e29)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum('bkgs,bskv->bkgv', p.astype(v_eff.dtype),
                   v_eff).astype(jnp.float32)
    if axis_names:
        l = jax.lax.psum(l, axis_names)
        o = jax.lax.psum(o, axis_names)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(B, H, Dv)
    return out.astype(q.dtype), new_cache


def decode_mla_reference(q_nope_lat, q_rope, new_ckv, new_kr, cache, cur, *,
                         axis_names=()):
    """Absorbed-MLA decode: attention in the compressed latent space.

    q_nope_lat: (B,H,r) — q_nope already absorbed through wk_b;
    q_rope: (B,H,dr); cache: {'ckv': (B,Sc,r), 'kr': (B,Sc,dr), 'meta'}.
    Returns (out_latent (B,H,r), new_cache) — caller up-projects via wv_b.
    """
    cache_ckv, cache_kr, meta = cache['ckv'], cache['kr'], cache['meta']
    B, Sc, r = cache_ckv.shape
    H = q_rope.shape[1]
    slot_ids, positions = meta['slots'], meta['pos']
    write_slot = jnp.mod(cur, meta['total'])
    offset = slot_ids[0]
    loc = jnp.clip(write_slot - offset, 0, Sc - 1)
    owned = (write_slot >= offset) & (write_slot - offset < Sc)

    def wr(cache_, new):
        curslice = jax.lax.dynamic_slice_in_dim(cache_, loc, 1, axis=1)
        upd = jnp.where(owned, new[:, None].astype(cache_.dtype), curslice)
        return jax.lax.dynamic_update_slice_in_dim(cache_, upd, loc, axis=1)

    cache_ckv = wr(cache_ckv, new_ckv)
    cache_kr = wr(cache_kr, new_kr)
    pos_upd = jnp.where(owned, cur[None], jax.lax.dynamic_slice_in_dim(
        positions, loc, 1))
    positions = jax.lax.dynamic_update_slice_in_dim(positions, pos_upd,
                                                    loc, axis=0)

    # deepseek scales by 1/sqrt(rope_dim + nope_dim); the caller pre-scales q
    # (before absorption), so the merge here is a plain sum of dot products.
    logits = (jnp.einsum('bhr,bsr->bhs', q_nope_lat.astype(jnp.float32),
                         cache_ckv.astype(jnp.float32))
              + jnp.einsum('bhd,bsd->bhs', q_rope.astype(jnp.float32),
                           cache_kr.astype(jnp.float32)))
    valid = (positions >= 0) & (positions <= cur)
    logits = jnp.where(valid[None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    for ax in axis_names:
        m = jax.lax.pmax(m, ax)
    m = jnp.maximum(m, -1e29)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum('bhs,bsr->bhr', p, cache_ckv.astype(jnp.float32))
    if axis_names:
        l = jax.lax.psum(l, axis_names)
        o = jax.lax.psum(o, axis_names)
    out_lat = o / jnp.maximum(l, 1e-30)[..., None]
    return out_lat, {'ckv': cache_ckv, 'kr': cache_kr,
                     'meta': dict(meta, pos=positions)}


# ------------------------------------------------------------------ GQA block apply


def gqa_forward(p, x, positions, cfg, *, kind, quant=(0, 0), kv=None):
    """Train/prefill attention. Returns (out, (k, v)) — k/v for cache fill.

    ``kv`` overrides k/v inputs (cross-attention: kv = encoder output tuple).
    """
    B, S, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p['wq'], x, quant=quant).reshape(B, S, H, hd)
    if kv is None:
        k = dense(p['wk'], x, quant=quant).reshape(B, S, K, hd)
        v = dense(p['wv'], x, quant=quant).reshape(B, S, K, hd)
        q = rope(q, positions, theta=cfg.rope_theta)
        k = rope(k, positions, theta=cfg.rope_theta)
        k_pos, causal = positions, kind != 'encoder'
    else:
        enc, enc_pos = kv
        k = dense(p['wk'], enc, quant=quant).reshape(B, enc.shape[1], K, hd)
        v = dense(p['wv'], enc, quant=quant).reshape(B, enc.shape[1], K, hd)
        k_pos, causal = enc_pos, False
    window = cfg.window if kind == 'local' else 0
    out = chunked_attention(q, k, v, positions, k_pos, causal=causal,
                            window=window, attn_softcap=cfg.attn_softcap)
    out = dense(p['wo'], out.reshape(B, S, H * hd), quant=quant)
    return out, (k, v)


def gqa_decode(p, x, cur, cfg, *, kind, cache, ctx, quant=(0, 0)):
    """One-token decode. x: (B, d). Returns (out, new_cache)."""
    B, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos1 = cur[None] if cur.ndim == 0 else cur
    q = dense(p['wq'], x[:, None], quant=quant).reshape(B, 1, H, hd)
    nk = dense(p['wk'], x[:, None], quant=quant).reshape(B, 1, K, hd)
    nv = dense(p['wv'], x[:, None], quant=quant).reshape(B, 1, K, hd)
    q = rope(q, pos1, theta=cfg.rope_theta)[:, 0]
    nk = rope(nk, pos1, theta=cfg.rope_theta)[:, 0]
    nv = nv[:, 0]
    window = cfg.window if kind == 'local' else 0
    fn = ctx.get('decode_attn', decode_attn_reference)
    out, new_cache = fn(q, nk, nv, cache, cur, window=window,
                        attn_softcap=cfg.attn_softcap)
    out = dense(p['wo'], out.reshape(B, H * hd), quant=quant)
    return out, new_cache


def gqa_cross_decode(p, x, enc, enc_pos, cfg, *, quant=(0, 0)):
    """Cross-attention for one decoder token against full encoder output."""
    B, d = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p['wq'], x, quant=quant).reshape(B, 1, H, hd)
    k = dense(p['wk'], enc, quant=quant).reshape(B, enc.shape[1], K, hd)
    v = dense(p['wv'], enc, quant=quant).reshape(B, enc.shape[1], K, hd)
    out = chunked_attention(q, k, v, jnp.zeros((1,), jnp.int32), enc_pos,
                            causal=False)
    return dense(p['wo'], out.reshape(B, H * hd), quant=quant)


# ------------------------------------------------------------------ MLA block apply


def mla_forward(p, x, positions, cfg, *, quant=(0, 0)):
    """Train/prefill MLA. Returns (out, (ckv, k_rope)) for cache fill."""
    B, S, d = x.shape
    H = cfg.num_heads
    dr, dn, dv = cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    from repro.models.layers import rms_norm
    cq = rms_norm(p['q_norm'], dense(p['wq_a'], x, quant=quant), cfg.norm_eps)
    q = dense(p['wq_b'], cq, quant=quant).reshape(B, S, H, dr + dn)
    q_rope, q_nope = q[..., :dr], q[..., dr:]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)

    kv_a = dense(p['wkv_a'], x, quant=quant)
    ckv = rms_norm(p['kv_norm'], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                  theta=cfg.rope_theta)[..., 0, :]                  # (B,S,dr)

    k_nope = jnp.einsum('bsr,rhn->bshn', ckv, p['wk_b'].astype(ckv.dtype))
    v = jnp.einsum('bsr,rhv->bshv', ckv, p['wv_b'].astype(ckv.dtype))
    k = jnp.concatenate(
        [jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr)), k_nope], axis=-1)
    q_full = jnp.concatenate([q_rope, q_nope], axis=-1)
    out = chunked_attention(q_full, k, v, positions, positions, causal=True)
    out = dense(p['wo'], out.reshape(B, S, H * dv), quant=quant)
    return out, (ckv, k_rope)


def mla_decode(p, x, cur, cfg, *, cache, ctx, quant=(0, 0)):
    B, d = x.shape
    H = cfg.num_heads
    dr, dn, dv = cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
    from repro.models.layers import rms_norm
    pos1 = cur[None]
    cq = rms_norm(p['q_norm'], dense(p['wq_a'], x, quant=quant), cfg.norm_eps)
    q = dense(p['wq_b'], cq, quant=quant).reshape(B, H, dr + dn)
    scale = (dr + dn) ** -0.5
    q_rope = rope(q[None, ..., :dr], pos1, theta=cfg.rope_theta)[0] * scale
    q_nope = q[..., dr:] * scale
    # absorb through wk_b: (B,H,dn) x (r,H,dn) -> (B,H,r)
    q_lat = jnp.einsum('bhn,rhn->bhr', q_nope, p['wk_b'].astype(q_nope.dtype))

    kv_a = dense(p['wkv_a'], x, quant=quant)
    new_ckv = rms_norm(p['kv_norm'], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    new_kr = rope(kv_a[:, None, None, cfg.kv_lora_rank:], pos1,
                  theta=cfg.rope_theta)[:, 0, 0]

    fn = ctx.get('decode_mla', decode_mla_reference)
    out_lat, new_cache = fn(q_lat, q_rope, new_ckv, new_kr, cache, cur)
    out = jnp.einsum('bhr,rhv->bhv', out_lat.astype(x.dtype),
                     p['wv_b'].astype(x.dtype))
    out = dense(p['wo'], out.reshape(B, H * dv), quant=quant)
    return out, new_cache


# ------------------------------------------------------------------- cache builders


def make_cache_meta(n_slots: int, local_offset: int = 0, local_len: int | None = None):
    ll = n_slots if local_len is None else local_len
    return {'slots': local_offset + jnp.arange(ll, dtype=jnp.int32),
            'pos': jnp.full((ll,), -1, jnp.int32),
            'total': jnp.asarray(n_slots, jnp.int32)}


def kv_quantize(x, axis=-1):
    """int8-quantize along head_dim with per-(token, head) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -128, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def kv_dequantize(q, s, dtype):
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def init_attn_cache(cfg, batch, kind, max_len, dtype):
    n = min(cfg.window, max_len) if kind == 'local' else max_len
    K, hd = cfg.num_kv_heads, cfg.head_dim
    c = {'k': jnp.zeros((batch, n, K, hd), dtype),
         'v': jnp.zeros((batch, n, K, hd), dtype),
         'meta': make_cache_meta(n)}
    if cfg.kv_cache_bits == 8:
        # int8 KV cache (the paper's Q pass at the cache level): halves the
        # dominant decode HBM traffic; per-(token, head) scales.
        c['k'] = jnp.zeros((batch, n, K, hd), jnp.int8)
        c['v'] = jnp.zeros((batch, n, K, hd), jnp.int8)
        c['k_s'] = jnp.zeros((batch, n, K), jnp.float32)
        c['v_s'] = jnp.zeros((batch, n, K), jnp.float32)
    return c


def init_mla_cache(cfg, batch, max_len, dtype):
    return {'ckv': jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            'kr': jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
            'meta': make_cache_meta(max_len)}


def prefill_cache_write(cache, k, v, positions):
    """Write prefill k/v (B,S,K,D) into a fresh cache (ring-aware)."""
    Sc = cache['k'].shape[1]
    S = k.shape[1]
    take = min(S, Sc)
    kt, vt = k[:, S - take:], v[:, S - take:]
    pt = positions[S - take:]
    slots = jnp.mod(pt, cache['meta']['total'])
    out = dict(cache)
    if 'k_s' in cache:
        kq, ks = kv_quantize(kt)
        vq, vs = kv_quantize(vt)
        out['k'] = cache['k'].at[:, slots].set(kq)
        out['v'] = cache['v'].at[:, slots].set(vq)
        out['k_s'] = cache['k_s'].at[:, slots].set(ks)
        out['v_s'] = cache['v_s'].at[:, slots].set(vs)
    else:
        out['k'] = cache['k'].at[:, slots].set(kt.astype(cache['k'].dtype))
        out['v'] = cache['v'].at[:, slots].set(vt.astype(cache['v'].dtype))
    out['meta'] = dict(cache['meta'],
                       pos=cache['meta']['pos'].at[slots].set(pt))
    return out


def prefill_mla_cache_write(cache, ckv, kr, positions):
    S = ckv.shape[1]
    slots = jnp.mod(positions, cache['meta']['total'])
    c1 = cache['ckv'].at[:, slots].set(ckv.astype(cache['ckv'].dtype))
    c2 = cache['kr'].at[:, slots].set(kr.astype(cache['kr'].dtype))
    pos = cache['meta']['pos'].at[slots].set(positions)
    return {'ckv': c1, 'kr': c2, 'meta': dict(cache['meta'], pos=pos)}
