"""CIFAR-style CNN family (ResNet / VGG / MobileNetV2) — the paper's own
architectures, in functional JAX.

Notes vs. the paper: BatchNorm is replaced by GroupNorm(8) to keep the model
purely functional (no running stats in the training state) — this does not
interact with the compression-order findings, which are about D/P/Q/E
sequencing.  Every conv/fc routes through the same fake-quant hook as the
transformers (cfg.w_bits / cfg.a_bits), channel pruning physically shrinks
conv channels, and early-exit heads hang off stage boundaries
(cfg.exit_stages).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quantization import fake_quant_act, fake_quant_weight


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan = kh * kw * cin
    return {'w': jax.random.normal(key, (kh, kw, cin, cout), dtype)
            * math.sqrt(2.0 / fan),
            'b': jnp.zeros((cout,), dtype)}


def conv(p, x, *, stride=1, quant=(0, 0), groups=1, name=None):
    """QAT/fp32 conv: per-call fake-quant hooks on weight and activation.

    This is the *training* path.  The serving path (core/export.py) swaps
    this out via cnn_forward's ``conv_fn`` for an int8 Pallas conv with
    static, export-time weight scales.  ``name`` is the stable layer name
    cnn_forward threads through (ignored here; the export layer-plan
    compiler keys its static-scale plan by it).

    A low-rank-factored conv (core/family.py factorize: {'u': spatial conv
    to rank r, 'v': 1x1 conv back up}) chains the two sub-convs; each gets
    its own fake-quant hooks, matching the exported int8 path.
    """
    del name
    if 'u' in p:
        h = conv(p['u'], x, stride=stride, quant=quant, groups=groups)
        return conv(p['v'], h, quant=quant)
    w_bits, a_bits = quant
    w = p['w']
    if w_bits:
        w = fake_quant_weight(w, w_bits, axis=-1)
    if a_bits:
        x = fake_quant_act(x, a_bits)
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), 'SAME',
        feature_group_count=groups,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    return y + p['b'].astype(y.dtype)


def out_channels(p) -> int:
    """Output channels of a conv/fc param dict (fp32 'w', int8 'w_q', or
    low-rank factored {'u','v'} — the 'v' half carries the output dim)."""
    if 'v' in p and 'w' not in p and 'w_q' not in p:
        return out_channels(p['v'])
    return (p['w'] if 'w' in p else p['w_q']).shape[-1]


def group_norm(p, x, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = math.gcd(groups, C)
    xg = x.reshape(B, H, W, g, C // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(B, H, W, C)
    return x * p['scale'] + p['bias']


def _norm_init(c, dtype=jnp.float32):
    return {'scale': jnp.ones((c,), dtype), 'bias': jnp.zeros((c,), dtype)}


def _fc_init(key, din, dout, dtype=jnp.float32):
    return {'w': jax.random.normal(key, (din, dout), dtype)
            * math.sqrt(1.0 / din),
            'b': jnp.zeros((dout,), dtype)}


def fc(p, x, *, quant=(0, 0), name=None):
    del name
    if 'u' in p:                   # low-rank factored: two chained matmuls
        return fc(p['v'], fc(p['u'], x, quant=quant), quant=quant)
    w_bits, a_bits = quant
    w = p['w']
    if w_bits:
        w = fake_quant_weight(w, w_bits, axis=-1)
    if a_bits:
        x = fake_quant_act(x, a_bits)
    y = x @ w.astype(x.dtype)
    return y + p['b'].astype(x.dtype) if 'b' in p else y


# ------------------------------------------------------------------------ init


def init_cnn(key, cfg):
    ks = iter(jax.random.split(key, 4096))
    p = {'stem': _conv_init(next(ks), 3, 3, cfg.in_channels,
                            cfg.stage_widths[0]),
         'stem_norm': _norm_init(cfg.stage_widths[0])}
    stages = []
    cin = cfg.stage_widths[0]
    for s, (n, w) in enumerate(zip(cfg.stage_blocks, cfg.stage_widths)):
        blocks = []
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            if cfg.kind == 'resnet':
                blk = {'conv1': _conv_init(next(ks), 3, 3, cin, w),
                       'n1': _norm_init(w),
                       'conv2': _conv_init(next(ks), 3, 3, w, w),
                       'n2': _norm_init(w)}
                if stride != 1 or cin != w:
                    blk['proj'] = _conv_init(next(ks), 1, 1, cin, w)
            elif cfg.kind == 'vgg':
                blk = {'conv1': _conv_init(next(ks), 3, 3, cin, w),
                       'n1': _norm_init(w)}
            else:  # mobilenet inverted residual
                e = cin * cfg.expand_ratio
                blk = {'expand': _conv_init(next(ks), 1, 1, cin, e),
                       'n1': _norm_init(e),
                       'dw': _conv_init(next(ks), 3, 3, 1, e),
                       'n2': _norm_init(e),
                       'project': _conv_init(next(ks), 1, 1, e, w),
                       'n3': _norm_init(w)}
            blocks.append(blk)
            cin = w
        stages.append(blocks)
    p['stages'] = stages
    p['head'] = _fc_init(next(ks), cin, cfg.num_classes)
    if cfg.exit_stages:
        p['exits'] = {str(s): _fc_init(next(ks), cfg.stage_widths[s],
                                       cfg.num_classes)
                      for s in cfg.exit_stages}
    return p


# -------------------------------------------------------------------- forward


_ACTS = {None: lambda x: x, 'relu': jax.nn.relu, 'relu6': jax.nn.relu6}


def norm_act(p, y, *, act=None, skip=None, name=None):
    """The inter-layer glue: GroupNorm -> (+skip) -> activation, fp32.

    Every tensor that travels between conv layers goes through exactly one
    ``glue_fn`` call — which is why core/export.py can swap this for an
    int8-resident version (dequantize in-register, norm/act in fp32
    registers, requantize to the next layer's static scale) and know that
    no activation reaches HBM in fp32.  ``name`` keys the export plan.
    """
    del name
    h = group_norm(p, y)
    if skip is not None:
        h = h + skip
    return _ACTS[act](h)


def global_pool(x):
    """Global average pool (B,H,W,C) -> (B,C) ahead of fc/exit heads."""
    return x.mean(axis=(1, 2))


def _block_forward(blk, x, kind, stride, quant, conv_fn, glue_fn,
                   name=''):
    if kind == 'resnet':
        h = glue_fn(blk['n1'],
                    conv_fn(blk['conv1'], x, stride=stride, quant=quant,
                            name=f'{name}.conv1'),
                    act='relu', name=f'{name}.n1')
        y = conv_fn(blk['conv2'], h, quant=quant, name=f'{name}.conv2')
        skip = conv_fn(blk['proj'], x, stride=stride, quant=quant,
                       name=f'{name}.proj') if 'proj' in blk else x
        return glue_fn(blk['n2'], y, act='relu', skip=skip,
                       name=f'{name}.n2')
    if kind == 'vgg':
        return glue_fn(blk['n1'],
                       conv_fn(blk['conv1'], x, stride=stride, quant=quant,
                               name=f'{name}.conv1'),
                       act='relu', name=f'{name}.n1')
    # mobilenet
    e = out_channels(blk['expand'])
    h = glue_fn(blk['n1'], conv_fn(blk['expand'], x, quant=quant,
                                   name=f'{name}.expand'),
                act='relu6', name=f'{name}.n1')
    h = glue_fn(blk['n2'], conv_fn(blk['dw'], h, stride=stride, quant=quant,
                                   groups=e, name=f'{name}.dw'),
                act='relu6', name=f'{name}.n2')
    skip = x if (stride == 1
                 and x.shape[-1] == out_channels(blk['project'])) else None
    return glue_fn(blk['n3'], conv_fn(blk['project'], h, quant=quant,
                                      name=f'{name}.project'),
                   skip=skip, name=f'{name}.n3')


def cnn_forward(params, cfg, x, *, collect_exits=False, conv_fn=None,
                fc_fn=None, glue_fn=None, pool_fn=None, start_stage=0,
                stop_stage=None):
    """x: (B, H, W, C) -> logits (B, classes); optionally exit logits dict.

    ``conv_fn``/``fc_fn``/``glue_fn``/``pool_fn`` inject the layer
    implementations: the default is the QAT fake-quant path
    (:func:`conv`/:func:`fc`/:func:`norm_act`/:func:`global_pool`);
    core/export.py injects int8 serving layers over the same topology, so
    training and serving cannot drift structurally.  Each call site carries
    a stable ``name`` (``s{stage}b{block}.conv1`` etc.) so the export
    layer-plan compiler can attach per-layer static activation scales.

    ``start_stage``/``stop_stage`` make the forward *stage-resumable* (the
    serving scheduler's continuous-batching split, core/export.py
    ``_make_stage_fns``):

    * ``start_stage=0`` runs the stem; ``start_stage=s > 0`` treats ``x``
      as the carry activation that left stage ``s - 1`` (whatever type the
      injected glue produced there — fp32 in QAT, an int8 ``QAct`` on the
      int8-resident plan) and skips the stem and earlier stages.
    * ``stop_stage=s`` stops after stage ``s`` and returns ``(exits, h)``
      — the exit logits collected in range plus the carry — WITHOUT running
      the final head.  ``stop_stage=None`` runs to the head as before.

    Layer names are position-stable, so a resumed segment reads the same
    export-plan entries the monolithic forward calibrated.
    """
    conv_fn = conv_fn or conv
    fc_fn = fc_fn or fc
    glue_fn = glue_fn or norm_act
    pool_fn = pool_fn or global_pool
    quant = (cfg.w_bits, cfg.a_bits)
    if start_stage == 0:
        h = glue_fn(params['stem_norm'],
                    conv_fn(params['stem'], x, quant=quant, name='stem'),
                    act='relu', name='stem.norm')
    else:
        h = x                                     # carry from stage s-1
    exits = {}
    for s, blocks in enumerate(params['stages']):
        if s < start_stage:
            continue
        if stop_stage is not None and s > stop_stage:
            break
        for b, blk in enumerate(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            h = _block_forward(blk, h, cfg.kind, stride, quant, conv_fn,
                               glue_fn, name=f's{s}b{b}')
        if collect_exits and 'exits' in params and str(s) in params['exits']:
            feat = pool_fn(h)
            exits[s] = fc_fn(params['exits'][str(s)], feat, quant=quant,
                             name=f'exit{s}')
    if stop_stage is not None:
        return exits, h                           # mid-network segment
    feat = pool_fn(h)
    logits = fc_fn(params['head'], feat, quant=quant, name='head')
    if collect_exits:
        return logits, exits
    return logits
