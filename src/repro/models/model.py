"""Unified model API: ``build_model(cfg) -> Model`` for any ModelConfig.

The Model bundles init / forward / prefill / decode closures so the
training loop, serving path, compression chain, and dry-run all drive
architectures uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                  # (key) -> params
    forward: Callable               # (params, batch, **kw) -> logits
    prefill: Callable               # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable           # (params, token, cur, cache, state, ctx) -> ...
    init_cache: Callable            # (batch, max_len) -> cache
    encode: Any = None              # encdec only


def _batch_parts(cfg, batch):
    """Split a batch dict into (tokens, embeds, enc_frames)."""
    tokens = batch['tokens']
    embeds = batch.get('patches') if cfg.arch_kind == 'vlm' else None
    frames = batch.get('frames') if cfg.arch_kind == 'encdec' else None
    return tokens, embeds, frames


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        return tfm.init_lm(key, cfg)

    def forward(params, batch, *, remat=False, collect_hiddens=False):
        tokens, embeds, frames = _batch_parts(cfg, batch)
        enc = enc_pos = None
        if frames is not None:
            enc = tfm.encode(params, cfg, frames)
            enc_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        return tfm.forward(params, cfg, tokens, embeds=embeds, enc=enc,
                           enc_pos=enc_pos, remat=remat,
                           collect_hiddens=collect_hiddens)

    def prefill(params, batch, *, max_len):
        tokens, embeds, frames = _batch_parts(cfg, batch)
        enc = enc_pos = None
        if frames is not None:
            enc = tfm.encode(params, cfg, frames)
            enc_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        return tfm.prefill(params, cfg, tokens, embeds=embeds, enc=enc,
                           enc_pos=enc_pos, max_len=max_len)

    def decode_step(params, token, cur, cache, *, enc=None, ctx=None):
        enc_pos = None
        if enc is not None:
            enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
        return tfm.decode_step(params, cfg, token, cur, cache, ctx=ctx,
                               enc=enc, enc_pos=enc_pos)

    def init_cache(batch, max_len):
        return tfm.init_cache(cfg, batch, max_len)

    encode = (lambda params, frames: tfm.encode(params, cfg, frames)) \
        if cfg.arch_kind == 'encdec' else None

    return Model(cfg=cfg, init=init, forward=forward, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache, encode=encode)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
