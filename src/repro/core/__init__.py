# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Importing any core submodule populates the compression-pass registry
# (core/registry.py) with the built-in passes: D/P/Q/E from core/passes.py
# and the low-rank 'L' pass from core/lowrank.py.  Third-party passes
# register themselves the same way lowrank does.
from repro.core import passes as _passes          # noqa: F401  (registers DPQE)
from repro.core import lowrank as _lowrank        # noqa: F401  (registers L)
