"""First-class compression-pass registry — the N-pass generalization.

The paper's insertion theorem (Sec. 2) says adding a compression between two
others preserves their pairwise order, so the framework must not hardwire a
closed set of passes.  This module makes passes registrable data:

* :class:`CompressionPass` — key + (kind, granularity) metadata (the two
  axes the paper's sequence law is stated in), a *typed* hyperparameter
  dataclass, and the transform ``fn(state, hp, trainer) -> state``.
* a process-global registry: :func:`register` / :func:`unregister` /
  :func:`get_pass` / :func:`registered_keys`.  Third-party passes register
  without touching core — ``chain.Pipeline``, ``planner.theoretical_order``
  and the pairwise benchmarks all iterate the registry.

Migration note: the old closed ``core.passes.PASSES`` dict is now a live
read-only view of this registry, so existing ``PASSES['Q'].apply(...)``
call sites keep working and *see* newly registered passes.

Ordering: a pass ranks by ``(kind, granularity)`` — static before dynamic,
large granularity before small (the paper's principle).  Two passes in the
same class (e.g. low-rank 'L' and quantization 'Q', both static/sub-neuron)
are outside the theory; ties break deterministically by key so
``theoretical_order`` and the planner's topological sort agree.  An
empirical pairwise edge, when present, always overrides the tiebreak.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

# Rank tables for the paper's two ordering axes.  The planner imports these;
# check_consistency() enforces that every registered pass uses known values.
KIND_RANK = {'static': 0, 'dynamic': 1}
GRANULARITY_RANK = {'architecture': 0, 'neuron': 1, 'sub-neuron': 2}


@dataclass(frozen=True)
class CompressionPass:
    """A registrable compression pass: metadata + typed hps + transform."""
    key: str             # single uppercase letter, e.g. 'Q'
    name: str            # human-readable, e.g. 'quantization'
    kind: str            # static | dynamic
    granularity: str     # architecture | neuron | sub-neuron
    hp_cls: type         # hyperparameter dataclass (typed, with defaults)
    fn: Callable         # (state, hp: hp_cls, trainer) -> state

    @property
    def rank(self) -> tuple:
        """Sort key of the sequence law: static→dynamic, large→small
        granularity; same-class ties break by key (deterministic)."""
        return (KIND_RANK[self.kind], GRANULARITY_RANK[self.granularity],
                self.key)

    def resolve_hp(self, hp: Any = None):
        """Coerce ``hp`` (None | dict | hp_cls) to the typed dataclass.

        Unknown dict keys raise — a typo like ``{'w_bit': 4}`` must not be
        silently ignored (it used to be, with untyped ``hp.get`` dicts).
        """
        if hp is None:
            return self.hp_cls()
        if isinstance(hp, self.hp_cls):
            return hp
        if isinstance(hp, dict):
            known = {f.name for f in dataclasses.fields(self.hp_cls)}
            unknown = sorted(set(hp) - known)
            if unknown:
                raise TypeError(
                    f'pass {self.key!r} ({self.hp_cls.__name__}) got unknown '
                    f'hyperparameters {unknown}; known: {sorted(known)}')
            return self.hp_cls(**hp)
        raise TypeError(f'pass {self.key!r} hyperparameters must be None, '
                        f'dict, or {self.hp_cls.__name__}; got {type(hp)}')

    def apply(self, state, hp, trainer):
        """Resolve hps and run the transform (dict hps are coerced)."""
        return self.fn(state, self.resolve_hp(hp), trainer)


# ----------------------------------------------------------------- registry


_REGISTRY: dict[str, CompressionPass] = {}


def register(pass_: CompressionPass, *, replace: bool = False
             ) -> CompressionPass:
    """Register a pass under its key.  Raises on key collisions unless
    ``replace=True`` (a third-party pass must not shadow silently)."""
    key = pass_.key
    if not (isinstance(key, str) and len(key) == 1 and key.isalpha()
            and key.isupper()):
        raise ValueError(f'pass key must be a single uppercase letter, '
                         f'got {key!r}')
    if key in _REGISTRY and not replace:
        raise ValueError(f'pass key {key!r} already registered '
                         f'({_REGISTRY[key].name}); use replace=True')
    _check_one(pass_)
    _REGISTRY[key] = pass_
    return pass_


def unregister(key: str) -> CompressionPass:
    """Remove and return a registered pass (tests use this to round-trip)."""
    try:
        return _REGISTRY.pop(key)
    except KeyError:
        raise KeyError(f'pass {key!r} is not registered '
                       f'(have {registered_keys()})') from None


def get_pass(key: str) -> CompressionPass:
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(f'unknown pass {key!r} '
                       f'(registered: {registered_keys()})') from None


def registered_keys() -> tuple:
    """All registered pass keys, sorted alphabetically."""
    return tuple(sorted(_REGISTRY))


def registered() -> dict:
    """Snapshot {key: CompressionPass} of the current registry."""
    return dict(_REGISTRY)


# -------------------------------------------------------------- consistency


def _check_one(p: CompressionPass) -> None:
    if p.kind not in KIND_RANK:
        raise ValueError(f'pass {p.key!r}: unknown kind {p.kind!r} '
                         f'(planner ranks: {sorted(KIND_RANK)})')
    if p.granularity not in GRANULARITY_RANK:
        raise ValueError(f'pass {p.key!r}: unknown granularity '
                         f'{p.granularity!r} '
                         f'(planner ranks: {sorted(GRANULARITY_RANK)})')
    if not dataclasses.is_dataclass(p.hp_cls):
        raise ValueError(f'pass {p.key!r}: hp_cls must be a dataclass, '
                         f'got {p.hp_cls!r}')
    # every hp must have a default: Pipeline instantiates hp_cls() when no
    # hps are given for the pass
    for f in dataclasses.fields(p.hp_cls):
        if (f.default is dataclasses.MISSING
                and f.default_factory is dataclasses.MISSING):
            raise ValueError(f'pass {p.key!r}: hp field {f.name!r} '
                             f'needs a default value')
    if not callable(p.fn):
        raise ValueError(f'pass {p.key!r}: fn must be callable')


def check_consistency() -> tuple:
    """Validate every registered pass against the planner's rank tables.

    CI runs this (scripts/ci.sh): a registered pass with metadata the
    planner cannot rank would silently break ``theoretical_order`` and
    topological tie-breaking.  Returns the checked keys.
    """
    for p in _REGISTRY.values():
        _check_one(p)
    return registered_keys()
