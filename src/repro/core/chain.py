"""CompressionChain: apply passes in a given order (the paper's pipeline).

``Pipeline`` is the first-class chain API over the pass registry
(core/registry.py):

    Pipeline.from_sequence('DPLQE', hps).run(family, cfg, trainer)
    Pipeline.auto(planner).run(...)        # order from pairwise experiments

``from_sequence`` validates the sequence against the registry (unknown
keys, duplicates) and resolves each pass's hyperparameters into its typed
dataclass up front — an ``hps`` entry whose key is not in the sequence, or
a misspelled hyperparameter name, raises instead of being silently ignored.
``run`` trains the baseline (unless a shared one is passed), applies each
pass with fine-tuning, and records (accuracy, BitOpsCR, CR) after every
stage — the data behind the paper's Fig. 15 / Tables 1–4.

Migration note: ``run_chain(family, cfg, 'DPQE', hps, trainer)`` is kept as
a thin wrapper over ``Pipeline.from_sequence(...).run(...)`` and now
accepts any registered key set (e.g. 'DPLQE' once core/lowrank.py — or a
third-party pass — is registered).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax

from repro.core import registry
from repro.core.passes import ChainState, Trainer, init_chain_state

OPTIMAL_SEQUENCE = 'DPQE'   # the paper's own 4-pass combinational law


@dataclass(frozen=True)
class Pipeline:
    """A validated, hp-resolved sequence of registered compression passes."""
    steps: tuple     # ((CompressionPass, typed hp), ...)

    @classmethod
    def from_sequence(cls, sequence: str, hps: dict | None = None, *,
                      allow_repeats: bool = False,
                      verify_order: bool = False) -> 'Pipeline':
        """Build from a key string like 'DPLQE' and optional per-key hps.

        ``hps`` maps pass key -> dict or typed hp dataclass.  Raises on
        unknown pass keys, on hps entries for keys not in the sequence
        (typo guard), and on duplicate keys unless ``allow_repeats=True``
        (the repeat-compression experiments opt in deliberately).

        ``verify_order=True`` additionally lints the sequence against the
        theoretical order DAG via the analyzer's order-dag rule and raises
        :class:`~repro.analysis.report.AnalysisError` naming the violated
        edge.  Opt-in: running a deliberately wrong order (the pairwise
        experiments, ablations) is a feature, not a bug.
        """
        hps = dict(hps or {})
        seq = list(sequence)
        if not seq:
            raise ValueError('empty pass sequence')
        dups = sorted({k for k in seq if seq.count(k) > 1})
        if dups and not allow_repeats:
            raise ValueError(
                f'duplicate pass keys {dups} in sequence {sequence!r}; '
                f'pass allow_repeats=True if the repetition is intended')
        stray = sorted(set(hps) - set(seq))
        if stray:
            raise ValueError(
                f'hps given for keys {stray} not in sequence {sequence!r} '
                f'(registered passes: {registry.registered_keys()})')
        steps = tuple((p, p.resolve_hp(hps.get(k)))
                      for k in seq for p in (registry.get_pass(k),))
        pipe = cls(steps)
        if verify_order:
            pipe.verify_order(strict=True)
        return pipe

    def verify_order(self, *, strict: bool = False):
        """Lint this pipeline's sequence against the theoretical order DAG
        (the analyzer's order-dag rule) and return the AnalysisReport;
        ``strict=True`` raises AnalysisError on a violated edge."""
        from repro.analysis import check
        return check(sequence=self, rules=('order-dag',), strict=strict,
                     target=f'Pipeline {self.sequence!r}')

    @classmethod
    def auto(cls, planner, hps: dict | None = None) -> 'Pipeline':
        """Order from an OrderPlanner's pairwise DAG (or a benchmark results
        dict carrying 'topological_order')."""
        if hasattr(planner, 'topological_order'):
            seq = planner.topological_order()
        else:
            seq = planner['topological_order']
        return cls.from_sequence(seq, hps)

    @property
    def sequence(self) -> str:
        return ''.join(p.key for p, _ in self.steps)

    def run(self, family, cfg, trainer: Trainer, *, key=None,
            state: ChainState | None = None,
            pretrain_steps=None, checkpoint_dir=None) -> ChainState:
        """Apply the passes in order, fine-tuning and recording metrics.

        Returns the final ChainState; ``state.history`` holds per-stage
        metrics.  Pass an existing baseline ``state`` to reuse one trained
        original model across different sequences (how the paper compares
        orders fairly).

        ``checkpoint_dir`` persists the ChainState after the baseline and
        after every pass (checkpoint/chain_io.py: atomic step dirs, step =
        passes applied) and RESUMES from the newest committed step on the
        next call — a preempted long chain re-runs only the pass it died
        in, and the serving model registry (repro/serving/registry.py)
        loads the same artifacts.  A passed-in ``state`` takes precedence
        over any checkpoint on disk.
        """
        start = 0
        if state is None and checkpoint_dir is not None:
            from repro.checkpoint.chain_io import load_chain_state
            from repro.checkpoint.manager import latest_step
            if latest_step(checkpoint_dir) is not None:
                state, start = load_chain_state(checkpoint_dir, family)
                if start > len(self.steps):
                    raise ValueError(
                        f'checkpoint at {checkpoint_dir} has {start} passes '
                        f'applied but this pipeline only runs '
                        f'{len(self.steps)} ({self.sequence!r})')
                # the on-disk chain must be a prefix of THIS pipeline: the
                # history records one entry per applied pass, so the last
                # `start` labels must equal this sequence's first keys —
                # resuming a 'PQ' checkpoint under a 'DP' pipeline is an
                # error, not a silent skip of different passes
                applied = [h.get('pass')
                           for h in state.history][-start:] if start else []
                want = [p.key for p, _ in self.steps[:start]]
                if applied != want:
                    raise ValueError(
                        f'checkpoint at {checkpoint_dir} was produced by '
                        f'passes {applied} but this pipeline starts with '
                        f'{want} ({self.sequence!r}); use a fresh '
                        f'checkpoint_dir')
        if state is None:
            state = init_chain_state(family, cfg, key or jax.random.key(0),
                                     trainer, pretrain_steps=pretrain_steps)
            self._save(checkpoint_dir, state, 0)
        for i, (p, hp) in enumerate(self.steps):
            if i < start:
                continue                         # already applied on disk
            state = p.fn(state, hp, trainer)     # hp already resolved
            state.metrics(trainer, p.key)
            self._save(checkpoint_dir, state, i + 1)
        return state

    @staticmethod
    def _save(checkpoint_dir, state, step):
        if checkpoint_dir is not None:
            from repro.checkpoint.chain_io import save_chain_state
            save_chain_state(checkpoint_dir, state, step=step)

    def export(self, state: ChainState, *, use_pallas=None) -> Any:
        """Compile the finished chain for serving (core/export.py backend
        registry picks the family's serving path)."""
        from repro.core.export import export_chain
        return export_chain(state, use_pallas=use_pallas)


def run_chain(family, cfg, sequence: str, hps: dict, trainer: Trainer, *,
              key=None, state: ChainState | None = None,
              pretrain_steps=None, allow_repeats: bool = False):
    """Apply ``sequence`` (e.g. 'DPQE'). hps: {pass_key: hp dict/dataclass}.

    Thin wrapper over :class:`Pipeline` — see its docstrings for validation
    and reuse semantics.
    """
    pipe = Pipeline.from_sequence(sequence, hps, allow_repeats=allow_repeats)
    return pipe.run(family, cfg, trainer, key=key, state=state,
                    pretrain_steps=pretrain_steps)


def sweep_exit_thresholds(state: ChainState, trainer: Trainer, thresholds):
    """Each trained early-exit model yields a frontier over thresholds
    (the paper: 'each case with Early Exit provides several samples')."""
    fam = state.family
    batches = fam.eval_batches(trainer.eval_n, trainer.eval_batch)
    out = []
    for t in thresholds:
        acc, probs = fam.exit_stats(state.params, state.cfg, batches, t)
        bops = fam.bitops(state.cfg, probs, state.mac_scale)
        out.append({'threshold': t, 'acc': acc,
                    'BitOpsCR': state.base_bitops / max(bops, 1)})
    return out
