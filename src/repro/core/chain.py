"""CompressionChain: apply passes in a given order (the paper's pipeline).

``run_chain(family, cfg, 'DPQE', hps, trainer)`` trains the baseline, applies
each pass with fine-tuning, and records (accuracy, BitOpsCR, CR) after every
stage — the data behind the paper's Fig. 15 / Tables 1–4.
"""
from __future__ import annotations

import jax

from repro.core.passes import PASSES, ChainState, Trainer, init_chain_state

OPTIMAL_SEQUENCE = 'DPQE'   # the paper's combinational sequence law


def run_chain(family, cfg, sequence: str, hps: dict, trainer: Trainer, *,
              key=None, state: ChainState | None = None,
              pretrain_steps=None):
    """Apply ``sequence`` (e.g. 'DPQE'). hps: {pass_key: hyperparam dict}.

    Returns the final ChainState; ``state.history`` holds per-stage metrics.
    Pass an existing baseline ``state`` to reuse one trained original model
    across different sequences (how the paper compares orders fairly).
    """
    if state is None:
        state = init_chain_state(family, cfg, key or jax.random.key(0),
                                 trainer, pretrain_steps=pretrain_steps)
    for p in sequence:
        if p not in PASSES:
            raise KeyError(f'unknown pass {p!r} (have {sorted(PASSES)})')
        state = PASSES[p].apply(state, hps.get(p, {}), trainer)
        state.metrics(trainer, p)
    return state


def sweep_exit_thresholds(state: ChainState, trainer: Trainer, thresholds):
    """Each trained early-exit model yields a frontier over thresholds
    (the paper: 'each case with Early Exit provides several samples')."""
    fam = state.family
    batches = fam.eval_batches(trainer.eval_n, trainer.eval_batch)
    out = []
    for t in thresholds:
        acc, probs = fam.exit_stats(state.params, state.cfg, batches, t)
        bops = fam.bitops(state.cfg, probs, state.prune_scale)
        out.append({'threshold': t, 'acc': acc,
                    'BitOpsCR': state.base_bitops / max(bops, 1)})
    return out
