"""Low-rank factorization pass 'L' — the registry's proof of openness.

SVD-splits conv / fc weights into a rank-``r`` pair (a spatial conv down to
``r`` channels followed by a 1x1 conv back up; for fc, two chained
matmuls), with ``r`` chosen per weight as the smallest rank keeping
``energy`` of the spectral energy, and factored only where it *saves* MACs
(``r * (kh*kw*cin + cout) < kh*kw*cin*cout``).  A fine-tune at lr/10
follows, like every static pass.  The heavy lifting is delegated to the
family's ``factorize`` hook (core/family.py), which also reports the
stage-MAC multiplier for the BitOps cost model; storage is physical (the
factored pytree simply holds fewer parameters).

Classification on the paper's axes: static (the factored network is fixed
after the pass) and sub-neuron (it rewrites the weight matrices inside a
layer, like quantization; cf. Carreira-Perpinan & Idelbayev's "combining
compressions", which chains low-rank with P and Q).  'L' and 'Q' share the
(static, sub-neuron) class, so their relative order is outside the paper's
theory; the registry breaks the tie by key (L before Q — factorize a
continuous weight matrix, then discretize it), giving the 5-pass law
D→P→L→Q→E, and an empirical pairwise L/Q edge overrides the tiebreak.

This module deliberately registers through the public API only — it is the
template for out-of-tree passes (no edits to chain.py / planner.py):

    from repro.core import registry
    registry.register(registry.CompressionPass(
        'L', 'low-rank', 'static', 'sub-neuron', LowRankHP, _lowrank))
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax

from repro.core import registry
from repro.core.passes import ChainState, Trainer


@dataclass(frozen=True)
class LowRankHP:
    energy: float = 0.95     # fraction of spectral energy the rank must keep
    min_rank: int = 4        # floor on the kept rank


def _lowrank(state: ChainState, hp: LowRankHP, trainer: Trainer) -> ChainState:
    fam = state.family
    params, cfg, scale = fam.factorize(state.params, state.cfg,
                                       energy=hp.energy,
                                       min_rank=hp.min_rank)
    params, _ = trainer.fit(fam, cfg, params, lr=trainer.lr / 10)
    # factorization rewrites layer topology: dynamic exit stats (if any)
    # are stale, like after P
    return replace(state, cfg=cfg, params=params,
                   lowrank_scale=state.lowrank_scale * scale,
                   key=jax.random.fold_in(state.key, 7),
                   exit_probs=None, dyn_accuracy=None)


registry.register(registry.CompressionPass(
    'L', 'low-rank', 'static', 'sub-neuron', LowRankHP, _lowrank))
