"""Order planner: pairwise experiments → DAG → topological sort.

This is the paper's roadmap (Sec. 2): run A→B and B→A for every pair,
decide the winner by Pareto-frontier dominance of (accuracy, BitOpsCR)
samples, collect the pairwise edges into a DAG, and topologically sort it
into the combinational sequence law.  ``theoretical_order()`` returns the
sequence implied by the paper's static→dynamic / large→small-granularity
principles without running anything — the experiments in
benchmarks/pairwise_order.py validate that the empirical DAG matches it.

All of it is generic over the pass registry (core/registry.py): the
planner plans whatever key set is registered — the paper's four, the five
with low-rank 'L', or any third-party extension — with no 'DPQE'
assumption.  Passes sharing a (kind, granularity) class rank by key
(deterministic tiebreak; the theory does not order same-class passes), and
an empirical pairwise edge always overrides the tiebreak.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core import registry


def pass_rank(key: str) -> tuple:
    """(kind, granularity, key) sort rank of a registered pass."""
    return registry.get_pass(key).rank


def theoretical_order(keys=None) -> str:
    """Static before dynamic; within static, large→small granularity.

    ``keys=None`` plans every registered pass.  Same-class passes order by
    key — deterministic, theory-agnostic (see registry docstring).
    """
    if keys is None:
        keys = registry.registered_keys()
    return ''.join(sorted(keys, key=pass_rank))


def theoretical_dag(keys=None) -> tuple:
    """The theory's order edges over ``keys`` (default: all registered).

    Returns ``((first, later), ...)`` — one edge per pass pair in
    *distinct* (kind, granularity) classes, ordered static→dynamic and
    large→small granularity.  Same-class pairs (e.g. 'L' and 'Q', both
    static/sub-neuron) get NO edge: their key tiebreak is a determinism
    convention, not a theorem, so a checker must not flag either order.
    The order-dag analyzer rule (repro/analysis) lints Pipeline sequences
    against exactly these edges, reporting the violated one.
    """
    if keys is None:
        keys = registry.registered_keys()
    edges = []
    for a, b in itertools.combinations(sorted(set(keys)), 2):
        ra, rb = pass_rank(a)[:2], pass_rank(b)[:2]
        if ra < rb:
            edges.append((a, b))
        elif rb < ra:
            edges.append((b, a))
    return tuple(edges)


# ------------------------------------------------------------ frontier logic


def pareto_frontier(samples):
    """samples: [(acc, cr)] → non-dominated subset sorted by cr."""
    pts = sorted(samples, key=lambda p: (-p[1], -p[0]))
    front, best_acc = [], -1.0
    for acc, cr in pts:                      # decreasing cr
        if acc > best_acc:
            front.append((acc, cr))
            best_acc = acc
    return front[::-1]


def frontier_score(samples, cr_range=None):
    """Area under the accuracy-vs-log(CR) Pareto frontier.

    Higher = better compression/accuracy trade-off.  ``cr_range`` fixes the
    integration window so two frontiers are compared on common support.
    """
    import math
    front = pareto_frontier(samples)
    if not front:
        return 0.0
    lo, hi = cr_range or (min(c for _, c in front), max(c for _, c in front))
    lo, hi = math.log(max(lo, 1.0)), math.log(max(hi, lo + 1e-9))
    if hi <= lo:
        return max(a for a, _ in front)
    # step-wise integration: acc achievable at compression >= c
    area, prev = 0.0, lo
    # frontier sorted by increasing cr; acc decreases as cr increases
    xs = [(math.log(max(c, 1.0)), a) for a, c in front]
    xs.sort()
    for i, (x, a) in enumerate(xs):
        x2 = xs[i + 1][0] if i + 1 < len(xs) else hi
        x, x2 = max(x, lo), min(max(x2, lo), hi)
        if x2 > x:
            area += a * (x2 - x)
    return area / (hi - lo)


def compare_orders(samples_ab, samples_ba, a: str | None = None,
                   b: str | None = None):
    """Decide the winning order between two sample sets on common support.

    Exact score ties are NOT experimental evidence for either order: with
    the pass keys given, a tie falls back to the theoretical
    (kind, granularity) principle; without them it stays 'AB' for backward
    compatibility.  Callers should record tied edges with ``margin=0.0``
    (= |score difference|) so ``OrderPlanner.resolve_cycles`` drops them
    first.
    """
    crs = [c for _, c in samples_ab + samples_ba if c > 0]
    rng = (min(crs), max(crs)) if crs else None
    sa = frontier_score(samples_ab, rng)
    sb = frontier_score(samples_ba, rng)
    if sa == sb and a is not None and b is not None:
        winner = 'AB' if pass_rank(a) <= pass_rank(b) else 'BA'
        return winner, sa, sb
    return ('AB' if sa >= sb else 'BA'), sa, sb


# --------------------------------------------------------------- DAG + sort


@dataclass
class OrderPlanner:
    """Pairwise-edge collector + topological sort over a key set.

    ``keys=None`` plans all registered passes at construction time.
    """
    keys: str | None = None
    edges: set = field(default_factory=set)      # (first, later)
    margins: dict = field(default_factory=dict)  # edge -> |scoreA - scoreB|

    def __post_init__(self):
        if self.keys is None:
            self.keys = ''.join(registry.registered_keys())
        for k in self.keys:
            registry.get_pass(k)                 # fail fast on unknown keys

    def add_pairwise(self, a: str, b: str, winner: str, margin: float = 1.0):
        e = (a, b) if winner == 'AB' else (b, a)
        self.edges.add(e)
        self.margins[e] = margin

    def resolve_cycles(self):
        """Drop weakest-margin edges until acyclic (reduced-budget pairwise
        experiments can produce weak flipped edges; the paper's full-budget
        DAG is acyclic — this recovers an order while reporting what was
        dropped).  Zero-margin (tied) edges go first; equal margins break
        deterministically by edge."""
        dropped = []
        while True:
            try:
                self.topological_order()
                return dropped
            except ValueError:
                weakest = min(self.edges, key=lambda e:
                              (self.margins.get(e, 0.0), e))
                self.edges.discard(weakest)
                dropped.append(weakest)

    def pairs(self):
        return list(itertools.combinations(self.keys, 2))

    def topological_order(self) -> str:
        nodes = set(self.keys)
        edges = set(self.edges)
        indeg = {n: 0 for n in nodes}
        for _, b in edges:
            indeg[b] += 1
        order = []
        ready = [n for n in nodes if indeg[n] == 0]
        while ready:
            # the paper's hypothesis is a unique sorting; break any tie by
            # the theoretical principles (and a full pairwise sweep leaves
            # no ties anyway)
            ready.sort(key=pass_rank)
            n = ready.pop(0)
            order.append(n)
            for a, b in list(edges):
                if a == n:
                    edges.discard((a, b))
                    indeg[b] -= 1
                    if indeg[b] == 0:
                        ready.append(b)
        if len(order) != len(nodes):
            raise ValueError('pairwise results contain a cycle — the '
                             "paper's acyclicity hypothesis is violated")
        return ''.join(order)
