"""Export pass: compile a finished compression chain into an int8 serving
function running on the Pallas kernels.

``export_chain`` routes through a per-family serving-backend registry
(:func:`register_serving_backend`) — third-party families plug in serving
the same way third-party passes plug into core/registry.py.

The chain (e.g. D→P→L→Q→E over the registered passes, core/passes.py /
core/lowrank.py) ends with *fake-quant* params: every forward still runs
fp32 convs/matmuls and recomputes per-channel weight abs-max scales per
call.  This module realizes the Q pass at inference in two tiers:

1. **Dynamic-scale path** (``calibrate=None``, the PR-1 behavior):
   weights are snapshotted to int8 once (static per-out-channel scales) and
   activations get one dynamic per-tensor abs-max per layer — every layer
   reads/writes fp32 activations in HBM.
2. **Int8-resident path** (``calibrate=<sample batch>``): a *layer-plan
   compiler* runs one eager calibration forward over the sample batch,
   records a static activation scale for every layer boundary, and compiles
   a plan that picks, per layer:

   * the **fused low-rank kernel** (kernels/lowrank_conv.py) — a factored
     (u, v) conv pair in ONE Pallas launch, rank intermediate in VMEM —
     when the lane-padded rank fits a single 128 tile AND **cost-based
     kernel selection** picks it: the plan prices fused vs chained per
     layer (``select_kernels='model'`` via the analytic
     ``lowering_costs`` block-geometry model, ``'measure'`` by timing
     both lowerings at export) and records the winner + why in the plan,
     so a known-slower kernel never ships;
   * the **chained** int8 kernels (u then v, both int8-resident) when the
     rank exceeds the envelope or selection prefers two launches;
   * the plain int8 conv/matmul kernels with the **requantize epilogue**
     (kernels/quant_matmul.py ``out_scale``) for unfactored layers;
   * the **depthwise kernel** (kernels/depthwise_conv.py) for grouped
     convs with per-group depth 1 — direct per-channel int8 MACs,
     int8-in/int8-out, so MobileNet's ``fallback_mac_fraction`` is 0.
     Only per-group depth > 1 (absent from this repo's families) keeps
     the declared fp32 ``lax.conv`` fallback the summary reports.

   Activation scales are static Python floats baked into the jaxpr; no
   abs-max pass ever reads an activation tensor at serve time.  Between
   layers activations travel as int8 (``QAct``): conv kernels emit int8
   via the requantize epilogue, and the glue stage (GroupNorm + skip +
   ReLU, injected over models/cnn.py ``glue_fn``) dequantizes in-register
   and requantizes to the consumer's static scale — fp32 only appears at
   the final/exit logits and inside declared fallback layers.

3. **Batched early exit** — the E pass's exit heads are served batched:
   every sample takes its earliest confident exit (softmax confidence over
   a threshold), vectorized with where-masks instead of per-sample control
   flow.  ``export_chain`` threads the chain's calibrated
   ``exit_threshold`` into the served model.

On CPU (``use_pallas=None`` → auto) the serving function runs the pure-jnp
reference path: identical math and static scales, with dense layers on a
real int8 einsum but convs running a ``lax.conv`` whose operands are
dequantized in one fused XLA pass (CPU has no int8 conv units).  The
genuine int8 conv tiles are the TPU path (Mosaic-compiled Pallas kernels).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize_params_for_serving
from repro.kernels import ops, ref
from repro.kernels.depthwise_conv import fits_depthwise
from repro.kernels.lowrank_conv import fits_fused, lowering_costs
from repro.models import cnn as cnn_lib


def _serving_bits(cfg) -> tuple[int, int]:
    """(w_bits, a_bits) the int8 kernels run at: the chain's QAT bits when
    they fit in int8, else 8 (fp32/no-QAT models serve as W8A8).  Weights
    go down to bits=1 (DoReFa sign*mean, via quantize_weight); activation
    quantization needs >= 2 bits for a nonzero qmax."""
    w_bits = cfg.w_bits if 0 < cfg.w_bits <= 8 else 8
    a_bits = cfg.a_bits if 1 < cfg.a_bits <= 8 else 8
    return w_bits, a_bits


def _serving_layers(use_pallas: bool, a_bits: int):
    """Dynamic-scale int8 layer implementations injected into cnn_forward
    (the PR-1 exported path; cf. the int8-resident plan below).

    Weight scales live in the params pytree (static); quant here is the
    cfg hook tuple, ignored for weights — that is the QAT/serving split.
    Low-rank factored params ({'u','v'} pairs from family.factorize, each
    half already int8+scale after quantize_params_for_serving) chain two
    kernel calls, mirroring the QAT dispatch in models/cnn.py.
    """
    def conv_fn(p, x, *, stride=1, quant=(0, 0), groups=1, name=None):
        del quant, name
        if 'u' in p:
            h = conv_fn(p['u'], x, stride=stride, groups=groups)
            return conv_fn(p['v'], h)
        return ops.quant_conv_nhwc(x, p['w_q'], p['scale'], p.get('b'),
                                   stride=stride, groups=groups,
                                   a_bits=a_bits, use_pallas=use_pallas)

    def fc_fn(p, x, *, quant=(0, 0), name=None):
        del quant, name
        if 'u' in p:
            return fc_fn(p['v'], fc_fn(p['u'], x))
        y = ops.quant_dense(x, p['w_q'], p['scale'], a_bits=a_bits,
                            per_row=False, use_pallas=use_pallas)
        return y + p['b'] if 'b' in p else y

    return conv_fn, fc_fn


# ------------------------------------------------ int8-resident layer plan


@dataclass(frozen=True)
class QAct:
    """An int8 activation travelling between layers with its static scale.

    ``scale`` is a Python float captured at export calibration — a jaxpr
    constant, never recomputed at serve time.  HBM sees the int8 ``q``
    alone.  Registered as a pytree (``q`` the leaf, ``scale`` static aux
    data) so a stage-resumable serving segment can return its int8 carry
    across the jit boundary and the next segment can consume it — the
    scheduler moves int8 bytes between stages, never fp32.
    """
    q: Any
    scale: float

    @property
    def shape(self):
        return self.q.shape


jax.tree_util.register_pytree_node(
    QAct, lambda a: ((a.q,), a.scale), lambda s, c: QAct(c[0], s))


def _deq(x):
    """In-register dequantize (identity on tensors already fp32)."""
    if isinstance(x, QAct):
        return x.q.astype(jnp.float32) * x.scale
    return x


@dataclass
class LayerPlan:
    """The layer-plan compiler's output: per-layer static scales + kernel
    choice, keyed by the stable layer names models/cnn.py threads through
    cnn_forward.  ``layers`` covers convs/fcs, ``glues`` the inter-layer
    norm/act boundaries."""
    layers: dict
    glues: dict
    a_qmax: float

    def summary(self) -> dict:
        """Deployed-cost summary: MACs by kernel class, launch counts, the
        MAC fraction still served by the dequantized fp32 fallback (only
        per-group-depth>1 grouped convs — depthwise layers run the int8
        kernel, so mobilenet reports 0.0 here), and the per-layer fused-vs-
        chained low-rank selection with its reason, so a shipped kernel
        choice is always explicable.

        Counts cover the plain serving path (``ServingModel.fn``); the
        early-exit heads — calibrated too, but only executed by
        ``fn_exits`` — are reported separately as ``n_exit_heads`` /
        ``exit_head_launches``."""
        main = {n: e for n, e in self.layers.items()
                if not n.startswith('exit')}
        exits = {n: e for n, e in self.layers.items()
                 if n.startswith('exit')}
        total = sum(e['macs'] for e in main.values())
        fallback = sum(e['macs'] for e in main.values() if e['fallback'])
        return {
            'n_layers': len(main),
            'n_fused_lowrank': sum(1 for e in main.values()
                                   if e.get('fused')),
            'n_chained_lowrank': sum(1 for e in main.values()
                                     if e.get('factored')
                                     and not e.get('fused')),
            'n_depthwise': sum(1 for e in main.values()
                               if e.get('depthwise')),
            'n_fallback': sum(1 for e in main.values() if e['fallback']),
            'kernel_launches': sum(e['launches'] for e in main.values()),
            'n_exit_heads': len(exits),
            'exit_head_launches': sum(e['launches'] for e in exits.values()),
            'total_macs': total,
            'fallback_mac_fraction': fallback / max(total, 1),
            'lowrank_selection': {n: e['selection'] for n, e in main.items()
                                  if e.get('selection')},
            'lowering_cost_delta': self._lowering_cost_delta(main),
        }

    @staticmethod
    def _lowering_cost_delta(main) -> dict:
        """Measured-vs-modeled lowering costs for every layer that a
        measure-mode export timed (empty otherwise): how far off the
        analytic ``lowering_costs`` block model was from the wall clock,
        and whether both agree on the fused/chained winner — the feedback
        loop that keeps the roofline model honest."""
        out = {}
        for n, e in main.items():
            sel = e.get('selection') or {}
            if 'modeled_fused_us' not in sel or 'fused_us' not in sel:
                continue
            model_choice = ('fused' if sel['modeled_fused_us']
                            <= sel['modeled_chained_us'] else 'chained')
            out[n] = {
                'measured_fused_us': round(sel['fused_us'], 1),
                'measured_chained_us': round(sel['chained_us'], 1),
                'modeled_fused_us': round(sel['modeled_fused_us'], 1),
                'modeled_chained_us': round(sel['modeled_chained_us'], 1),
                'fused_measured_over_modeled': round(
                    sel['fused_us'] / max(sel['modeled_fused_us'], 1e-9), 3),
                'chained_measured_over_modeled': round(
                    sel['chained_us'] / max(sel['modeled_chained_us'],
                                            1e-9), 3),
                'model_agrees': model_choice == sel['choice'],
            }
        return out


def _compile_layer_plan(params, cfg, x, a_qmax, fuse_lowrank=True,
                        select_kernels='model') -> LayerPlan:
    """One eager calibration forward (the QAT fake-quant math) that records
    a static activation scale at every layer boundary and picks the serving
    kernel per layer (fused low-rank / chained / plain / depthwise /
    fallback).

    Factored pairs inside the fused envelope are priced fused-vs-chained:
    ``select_kernels='model'`` (default) uses the analytic
    ``lowering_costs`` block-geometry model at the calibration batch
    geometry; ``'fused'`` forces the one-launch lowering; ``'measure'`` is
    resolved afterwards by :func:`_measure_lowrank_selection` (wall-clock
    on the export backend).  ``fuse_lowrank=False`` forces the chained
    two-launch lowering regardless (the benchmark A/B).  The decision and
    its reason land in ``e['selection']`` and the plan summary."""
    layers, glues = {}, {}

    def amax(v) -> float:
        return max(float(jnp.max(jnp.abs(v))), 1e-8)

    def conv_fn(p, cx, *, stride=1, quant=(0, 0), groups=1, name=None):
        depthwise = groups > 1 and 'u' not in p and fits_depthwise(
            p['w'].shape)
        e = {'sx': amax(cx) / a_qmax, 'kind': 'conv',
             'fallback': groups > 1 and not depthwise,
             'depthwise': depthwise, 'factored': 'u' in p, 'fused': False,
             'stride': stride, 'in_shape': tuple(cx.shape),
             'groups': groups,
             'w_shape': None if 'u' in p else tuple(p['w'].shape)}
        if 'u' in p:
            mid = cnn_lib.conv(p['u'], cx, stride=stride, quant=quant,
                               groups=groups)
            y = cnn_lib.conv(p['v'], mid, quant=quant)
            e['h_scale'] = amax(mid) / a_qmax
            kh, kw, cin, r = p['u']['w'].shape
            cout = p['v']['w'].shape[-1]
            oh, ow = y.shape[1], y.shape[2]
            e['macs'] = oh * ow * r * (kh * kw * cin + cout)
            if not fits_fused(r, cout):
                sel = {'choice': 'chained',
                       'why': f'rank {r} exceeds the fused envelope'}
            elif not fuse_lowrank:
                sel = {'choice': 'chained',
                       'why': 'fuse_lowrank=False (forced two-launch A/B)'}
            elif select_kernels == 'fused':
                sel = {'choice': 'fused',
                       'why': 'select_kernels=fused (forced)'}
            else:   # 'model' now; 'measure' re-decides from wall-clock after
                c = lowering_costs(y.shape[0] * oh * ow, kh * kw * cin, r,
                                   cout)
                ch = 'fused' if c['fused_us'] <= c['chained_us'] else \
                    'chained'
                sel = {'choice': ch,
                       'why': (f"modeled fused {c['fused_us']:.1f}us vs "
                               f"chained {c['chained_us']:.1f}us"),
                       'fused_us': c['fused_us'],
                       'chained_us': c['chained_us']}
            e['selection'] = sel
            e['fused'] = sel['choice'] == 'fused'
            e['launches'] = 1 if e['fused'] else 2
            e['rank'] = r
            e['kernel'] = (kh, kw)
        else:
            y = cnn_lib.conv(p, cx, stride=stride, quant=quant, groups=groups)
            kh, kw, cin, cout = p['w'].shape
            oh, ow = y.shape[1], y.shape[2]
            e['macs'] = oh * ow * kh * kw * cin * cout
            e['launches'] = 0 if e['fallback'] else 1
            e['kernel'] = (kh, kw)
        e['out_scale'] = amax(y) / a_qmax
        e['out_shape'] = tuple(y.shape)
        layers[name] = e
        return y

    def fc_fn(p, cx, *, quant=(0, 0), name=None):
        e = {'sx': amax(cx) / a_qmax, 'kind': 'fc', 'fallback': False,
             'factored': 'u' in p, 'fused': False, 'out_scale': None,
             'in_shape': tuple(cx.shape)}
        if 'u' in p:
            mid = cnn_lib.fc(p['u'], cx, quant=quant)
            y = cnn_lib.fc(p['v'], mid, quant=quant)
            e['h_scale'] = amax(mid) / a_qmax
            din, r = p['u']['w'].shape
            e['macs'] = r * (din + p['v']['w'].shape[-1])
            e['launches'] = 2
        else:
            y = cnn_lib.fc(p, cx, quant=quant)
            e['macs'] = p['w'].shape[0] * p['w'].shape[1]
            e['launches'] = 1
        e['out_shape'] = tuple(y.shape)
        layers[name] = e
        return y

    def glue_fn(np_, y, *, act=None, skip=None, name=None):
        h = cnn_lib.norm_act(np_, y, act=act, skip=skip)
        glues[name] = amax(h) / a_qmax
        return h

    cnn_lib.cnn_forward(params, cfg, x, collect_exits=True, conv_fn=conv_fn,
                        fc_fn=fc_fn, glue_fn=glue_fn)
    return LayerPlan(layers=layers, glues=glues, a_qmax=a_qmax)


def _conv_f32(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), 'SAME', feature_group_count=groups,
        dimension_numbers=('NHWC', 'HWIO', 'NHWC'))


def _depthwise_shift_conv(x, w, stride=1):
    """Depthwise SAME conv as kh*kw shifted multiply-accumulates.

    XLA CPU lowers ``feature_group_count=C`` convs to a per-group loop
    that is ~20x slower than these C-wide elementwise FMAs; on the
    int8-resident CPU plan the declared depthwise fallback uses this
    instead.  x fp32 (B,H,W,C); w fp32 (KH,KW,1,C) — already
    scale-folded.  Value-identical to lax.conv (same pads, fp32 FMAs).
    """
    B, H, W, C = x.shape
    kh, kw = w.shape[0], w.shape[1]
    oh, ow = -(-H // stride), -(-W // stride)
    pad_h = max((oh - 1) * stride + kh - H, 0)
    pad_w = max((ow - 1) * stride + kw - W, 0)
    x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                    (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    y = None
    for i in range(kh):
        for j in range(kw):
            t = x[:, i:i + (oh - 1) * stride + 1:stride,
                  j:j + (ow - 1) * stride + 1:stride, :] * w[i, j, 0]
            y = t if y is None else y + t
    return y


def _fold_conv_consts(plan: LayerPlan, qparams):
    """Export-time constant folding for the jnp (CPU) backend.

    CPU convs run fp32 ``lax.conv`` regardless (no int8 conv units), so
    the dequant multiplies are hoisted out of the serve loop entirely:
    each conv's int8 weight is dequantized ONCE here and pre-scaled by the
    layer's *static* input scale — ``conv(x_q*sx, w_q*sw) ==
    conv(x_q, w_q*(sx*sw))`` by bilinearity.  At serve time the activation
    only pays an int8→fp32 cast.  Keyed by layer name; baked into the
    jaxpr as constants (the ``params`` argument keeps the int8 contract
    for storage/HBM accounting)."""
    fold = {}
    # resolve each plan layer's param subtree by its name path
    # (s0b1.conv2 -> stages[0][1]['conv2']) and pre-scale the weights
    for name, e in plan.layers.items():
        p = _resolve_layer_params(qparams, name)
        if e['kind'] != 'conv':
            continue
        if e['factored']:
            u, v = p['u'], p['v']
            fold[name] = {
                'u_w': u['w_q'].astype(jnp.float32) * u['scale'] * e['sx'],
                'u_b': u.get('b', 0.0),
                'v_w': v['w_q'].astype(jnp.float32) * v['scale']
                       * e['h_scale'],
                'v_b': v.get('b', 0.0),
            }
        else:
            fold[name] = {'w': p['w_q'].astype(jnp.float32) * p['scale']
                          * e['sx'],
                          'b': p.get('b', 0.0)}
    return fold


def _resolve_layer_params(params, name: str):
    """Map a stable layer name from models/cnn.py (``s0b1.conv2``,
    ``stem``, ``exit1``, ``head``) to its param subtree."""
    head = name.split('.')[0]
    if head == 'stem':
        return params['stem']
    if head == 'head':
        return params['head']
    if head.startswith('exit'):
        return params['exits'][head[4:]]
    s, b = head[1:].split('b')
    return params['stages'][int(s)][int(b)][name.split('.')[1]]


def _measure_lowrank_selection(plan: LayerPlan, qparams, use_pallas: bool,
                               *, reps: int = 3, tracer=None) -> None:
    """Resolve ``select_kernels='measure'``: wall-clock fused vs chained.

    For every factored conv inside the fused envelope, times both lowerings
    on the export backend (zero int8 input at the calibration geometry —
    timing is data-independent, best of ``reps`` after a compile warmup)
    and rewrites ``e['selection']`` / ``e['fused']`` with the measured
    winner, so the plan cannot ship a variant the machine just proved
    slower.  Mutates the plan in place.

    The modeled costs the analytic pricing produced survive as
    ``modeled_fused_us``/``modeled_chained_us`` in the rewritten selection
    (the summary's ``lowering_cost_delta`` block), and each timed launch
    lands on ``tracer`` as a wall-clock ``kernel.launch`` span — the spans
    ARE the measurement the decision is made from."""
    import time
    from repro.obs.trace import as_tracer
    tracer = as_tracer(tracer)
    qmax = plan.a_qmax
    for name, e in plan.layers.items():
        if e['kind'] != 'conv' or not e['factored']:
            continue
        if e['selection']['choice'] == 'chained' and 'envelope' in \
                e['selection']['why']:
            continue                     # rank-ineligible: nothing to race
        p = _resolve_layer_params(qparams, name)
        u, v = p['u'], p['v']
        bu = u.get('b', jnp.zeros(u['w_q'].shape[-1], jnp.float32))
        bv = v.get('b', jnp.zeros(v['w_q'].shape[-1], jnp.float32))
        xq = jnp.zeros(e['in_shape'], jnp.int8)

        def fused():
            return ops.lowrank_conv_nhwc(
                xq, u['w_q'], v['w_q'], u['scale'], v['scale'], bu, bv,
                sx=e['sx'], h_scale=e['h_scale'], stride=e['stride'],
                out_scale=e['out_scale'], h_qmax=qmax, out_qmax=qmax,
                use_pallas=use_pallas)

        def chained():
            h = ops.quant_conv_static(
                xq, u['w_q'], u['scale'], bu, sx=e['sx'], stride=e['stride'],
                out_scale=e['h_scale'], out_qmax=qmax, use_pallas=use_pallas)
            return ops.quant_conv_static(
                h, v['w_q'], v['scale'], bv, sx=e['h_scale'],
                out_scale=e['out_scale'], out_qmax=qmax,
                use_pallas=use_pallas)

        def best_us(f, variant):
            f().block_until_ready()      # compile outside the clock
            ts = []
            for rep in range(reps):
                t0 = time.perf_counter()
                w0 = tracer.now()
                f().block_until_ready()
                us = (time.perf_counter() - t0) * 1e6
                tracer.add('kernel.launch', w0, w0 + us * 1e-6,
                           track='export', layer=name, variant=variant,
                           rep=rep, us=round(us, 1))
                ts.append(us)
            return min(ts)

        modeled = e['selection']          # the analytic pricing, pre-race
        tf = best_us(fused, 'fused')
        tc = best_us(chained, 'chained')
        e['selection'] = {'choice': 'fused' if tf <= tc else 'chained',
                          'why': (f'measured fused {tf:.0f}us vs chained '
                                  f'{tc:.0f}us'),
                          'fused_us': tf, 'chained_us': tc}
        if 'fused_us' in modeled:         # keep the model's claim on record
            e['selection']['modeled_fused_us'] = modeled['fused_us']
            e['selection']['modeled_chained_us'] = modeled['chained_us']
        e['fused'] = tf <= tc
        e['launches'] = 1 if e['fused'] else 2


def _resident_layers(plan: LayerPlan, use_pallas: bool, qparams=None):
    """Int8-resident layer implementations compiled from a LayerPlan.

    Pallas backend: convs consume/produce :class:`QAct` — int8 in HBM on
    static scales, requantize epilogues fused into the kernels, factored
    pairs in one launch when the rank fits.  The glue stage (GroupNorm +
    skip + activation) runs on the raw int8 codes (GroupNorm is invariant
    to the positive per-tensor scale, up to eps) and requantizes to its
    calibrated output scale — which by construction equals the consumer's
    input scale (both were recorded off the same tensor at calibration).

    jnp (CPU) backend: inter-layer tensors are the same int8 QActs, but
    inside a layer the conv carries fp32 (CPU has no int8 conv units, so
    an intra-layer int8 bounce would only add round-trips); all dequant
    multiplies are folded into export-time constants
    (:func:`_fold_conv_consts`), leaving one int8→fp32 cast per conv.

    Depthwise layers serve on the direct per-channel int8 kernel
    (kernels/depthwise_conv.py) on the Pallas backend — QAct in, QAct out,
    no fp32 in HBM — and on the scale-folded shift conv on CPU.  Only
    grouped convs with per-group depth > 1 remain the declared fp32
    fallback (QAct in, fp32 out, re-quantized by the next glue); none
    exist in this repo's families.
    """
    qmax = plan.a_qmax
    fold = None if use_pallas else _fold_conv_consts(plan, qparams)

    def as_qact(x, sx):
        if isinstance(x, QAct):
            return x
        return QAct(ref.requantize(x, sx, qmax), sx)

    def conv_fn(p, x, *, stride=1, quant=(0, 0), groups=1, name=None):
        del quant
        e = plan.layers[name]
        xq = as_qact(x, e['sx'])
        if e['fallback']:
            return ref.quant_conv_ref(xq.q, p['w_q'], xq.scale, p['scale'],
                                      p.get('b'), stride=stride,
                                      groups=groups)
        if not use_pallas:
            f = fold[name]
            xf = xq.q.astype(jnp.float32)
            if e.get('depthwise'):
                return _depthwise_shift_conv(xf, f['w'], stride) + f['b']
            if e['factored']:
                h = _conv_f32(xf, f['u_w'], stride) + f['u_b']
                h_q = ref.requantize(h, e['h_scale'], qmax)
                y = _conv_f32(h_q.astype(jnp.float32), f['v_w']) + f['v_b']
            else:
                y = _conv_f32(xf, f['w'], stride) + f['b']
            return y                     # fp32-carry to this layer's glue
        if e.get('depthwise'):
            y = ops.depthwise_conv_static(
                xq.q, p['w_q'], p['scale'], p.get('b'), sx=xq.scale,
                stride=stride, out_scale=e['out_scale'], out_qmax=qmax,
                use_pallas=True)
            return QAct(y, e['out_scale'])
        if e['factored']:
            u, v = p['u'], p['v']
            bu = u.get('b', jnp.zeros(u['w_q'].shape[-1], jnp.float32))
            bv = v.get('b', jnp.zeros(v['w_q'].shape[-1], jnp.float32))
            if e['fused']:
                y = ops.lowrank_conv_nhwc(
                    xq.q, u['w_q'], v['w_q'], u['scale'], v['scale'], bu, bv,
                    sx=xq.scale, h_scale=e['h_scale'], stride=stride,
                    out_scale=e['out_scale'], h_qmax=qmax, out_qmax=qmax,
                    use_pallas=True)
            else:
                h = ops.quant_conv_static(
                    xq.q, u['w_q'], u['scale'], bu, sx=xq.scale,
                    stride=stride, out_scale=e['h_scale'], out_qmax=qmax,
                    use_pallas=True)
                y = ops.quant_conv_static(
                    h, v['w_q'], v['scale'], bv, sx=e['h_scale'],
                    out_scale=e['out_scale'], out_qmax=qmax, use_pallas=True)
        else:
            y = ops.quant_conv_static(
                xq.q, p['w_q'], p['scale'], p.get('b'), sx=xq.scale,
                stride=stride, out_scale=e['out_scale'], out_qmax=qmax,
                use_pallas=True)
        return QAct(y, e['out_scale'])

    def fc_fn(p, x, *, quant=(0, 0), name=None):
        del quant
        e = plan.layers[name]
        xq = ref.requantize(_deq(x), e['sx'], qmax)
        if e['factored']:
            h = ops.quant_dense_static(
                xq, p['u']['w_q'], p['u']['scale'], p['u'].get('b'),
                sx=e['sx'], out_scale=e['h_scale'], out_qmax=qmax,
                use_pallas=use_pallas)
            return ops.quant_dense_static(
                h, p['v']['w_q'], p['v']['scale'], p['v'].get('b'),
                sx=e['h_scale'], use_pallas=use_pallas)
        return ops.quant_dense_static(xq, p['w_q'], p['scale'], p.get('b'),
                                      sx=e['sx'], use_pallas=use_pallas)

    def glue_fn(np_, y, *, act=None, skip=None, name=None):
        s = plan.glues[name]
        # GroupNorm is invariant to the input's positive per-tensor scale
        # (up to eps), so int8 inputs are normalized on their raw codes —
        # no dequantize multiply before the reduction.
        h = cnn_lib.group_norm(
            np_, y.q.astype(jnp.float32) if isinstance(y, QAct) else y)
        if skip is not None:
            h = h + _deq(skip)
        h = cnn_lib._ACTS[act](h)
        return QAct(ref.requantize(h, s, qmax), s)

    def pool_fn(h):
        if isinstance(h, QAct):           # scale the (B,C) mean, not the map
            return h.q.astype(jnp.float32).mean(axis=(1, 2)) * h.scale
        return h.mean(axis=(1, 2))

    return conv_fn, fc_fn, glue_fn, pool_fn


def _make_stage_fns(cfg, kw):
    """Split the compiled layer plan at the early-exit boundaries.

    Returns ``(stage_fns, stage_exits)``: one jit'd segment per exit
    boundary plus a final segment.  Segment ``i < last`` maps
    ``(params, carry) -> (exits, carry)`` where ``exits`` holds exactly the
    boundary head's logits and ``carry`` is whatever the injected glue
    produces at that stage boundary — an int8 :class:`QAct` on the
    int8-resident plan, fp32 on the dynamic path.  The final segment maps
    ``(params, carry) -> logits``.  ``stage_exits[i]`` names the exit
    stage segment ``i`` ends at (``None`` for the final segment).

    Chaining every segment is value-identical to the monolithic
    ``fn_exits`` — same layer names, same plan entries, same kernels — and
    bit-exact at fixed batch geometry; the request scheduler
    (repro/serving/) exploits the split to return exited samples after
    segment ``i`` and backfill their slots before paying for segment
    ``i + 1``.
    """
    bounds = tuple(sorted(cfg.exit_stages))
    fns, lo = [], 0
    for s in bounds:
        def seg(p, h, *, _lo=lo, _hi=s):
            return cnn_lib.cnn_forward(p, cfg, h, collect_exits=True,
                                       start_stage=_lo, stop_stage=_hi, **kw)
        fns.append(jax.jit(seg))
        lo = s + 1

    def final(p, h, *, _lo=lo):
        return cnn_lib.cnn_forward(p, cfg, h, start_stage=_lo, **kw)
    fns.append(jax.jit(final))
    return tuple(fns), bounds + (None,)


def exit_confidence(head_logits):
    """THE early-exit decision quantity: fp32 softmax max-confidence per
    sample.  Single definition shared by :func:`early_exit_batch`, the
    request scheduler (repro/serving/scheduler.py), and
    :func:`calibrate_exit_threshold` — a sample exits iff
    ``exit_confidence(head) > threshold``, strictly, everywhere."""
    return jax.nn.softmax(head_logits.astype(jnp.float32), axis=-1).max(-1)


def early_exit_batch(logits, exits, threshold):
    """Batched early-exit selection: (pred (B,), stage (B,) int32).

    Each sample takes the earliest exit whose :func:`exit_confidence`
    clears ``threshold``; stage is -1 for samples that ran to the final
    head.  Pure jnp (no per-sample control flow) so it jits into the
    serving fn.
    """
    pred = jnp.argmax(logits, -1)
    stage = jnp.full(pred.shape, -1, jnp.int32)
    taken = jnp.zeros(pred.shape, bool)
    for s in sorted(exits):
        take = (exit_confidence(exits[s]) > threshold) & ~taken
        pred = jnp.where(take, jnp.argmax(exits[s], -1), pred)
        stage = jnp.where(take, jnp.int32(s), stage)
        taken |= take
    return pred, stage


@dataclass
class ServingModel:
    """A compiled int8 serving endpoint for a compressed model."""
    cfg: Any
    params: Any                # int8 pytree: {'w_q', 'scale'(, 'b')} leaves
    fn: Callable               # jit'd (params, x) -> logits
    fn_exits: Callable | None = None   # jit'd (params, x) -> (logits, exits)
    plan: LayerPlan | None = None      # int8-resident exports only
    exit_threshold: float = 0.9        # E's operating point (export_chain)
    stage_fns: tuple | None = None     # layer plan split at exit boundaries
    stage_exits: tuple = ()            # exit stage each segment ends at
    backend: str = 'jnp'               # 'pallas' | 'jnp' serving lowering
    analysis: Any = None               # AnalysisReport from export verify=
    stage_devices: tuple = ()          # jax device pinned per segment
    stage_params: tuple | None = None  # params committed to stage_devices

    def serve(self, x):
        return self.fn(self.params, x)

    def serve_early_exit(self, x, threshold=None):
        """(pred, stage) per sample; requires exported exit heads.
        ``threshold=None`` uses the chain's calibrated operating point."""
        if self.fn_exits is None:
            raise ValueError('model was exported without exit heads')
        if x.shape[0] == 0:            # empty batch: nothing to run
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
        if threshold is None:
            threshold = self.exit_threshold
        logits, exits = self.fn_exits(self.params, x)
        return early_exit_batch(logits, exits, threshold)

    @property
    def n_stages(self) -> int:
        """Number of stage-resumable segments (0 = no exit heads)."""
        return len(self.stage_fns) if self.stage_fns else 0

    def run_stage(self, i: int, carry):
        """Run segment ``i`` of the stage-split plan.  ``carry`` is the
        input batch for ``i == 0``, else the carry segment ``i - 1``
        returned (int8 ``QAct`` on the resident plan).  Intermediate
        segments return ``(exits, carry)``; the last returns logits.
        On a placed model (:meth:`place_stages`) the segment reads the
        params copy committed to its device, so the computation runs
        where the placement put it."""
        if not self.stage_fns:
            raise ValueError('model was exported without exit heads '
                             '(no stage boundaries to resume at)')
        params = (self.stage_params[i] if self.stage_params is not None
                  else self.params)
        return self.stage_fns[i](params, carry)

    def place_stages(self, devices) -> 'ServingModel':
        """Pin segment ``k`` to ``devices[k]`` (one jax device per stage).

        Returns a NEW ServingModel whose ``stage_params[k]`` is the params
        pytree committed to ``devices[k]`` via ``jax.device_put`` (one
        transfer per *distinct* device — stages sharing a device share the
        copy).  Because committed operands pin where jit runs, every
        ``run_stage(k, ...)`` then executes on its assigned device; the
        compiled math is unchanged, so answers stay bit-exact with the
        unplaced model.  The int8 ``QAct`` carry between segments is NOT
        moved here — streaming it across stage boundaries is the
        scheduler's job (serving/placement.py)."""
        if not self.stage_fns:
            raise ValueError('model was exported without exit heads '
                             '(no stages to place)')
        devices = tuple(devices)
        if len(devices) != self.n_stages:
            raise ValueError(
                f'need one device per stage: got {len(devices)} devices '
                f'for {self.n_stages} stages')
        per_dev = {}
        for d in devices:
            if d not in per_dev:
                per_dev[d] = jax.device_put(self.params, d)
        return replace(self, stage_devices=devices,
                       stage_params=tuple(per_dev[d] for d in devices))

    def serve_stages(self, x):
        """Chain every stage segment: ``(logits, exits)``, value-identical
        to ``fn_exits(params, x)`` (the stage-split vs monolithic oracle)."""
        exits, h = {}, x
        for i in range(self.n_stages - 1):
            seg_exits, h = self.run_stage(i, h)
            exits.update(seg_exits)
        return self.run_stage(self.n_stages - 1, h), exits

    def summary(self) -> dict | None:
        """The layer plan's deployed-cost summary (int8-resident exports).
        Exports built with ``verify=`` carry their structured
        ``AnalysisReport`` under the ``analysis`` key."""
        if self.plan is None:
            return None
        s = self.plan.summary()
        if self.analysis is not None:
            s['analysis'] = self.analysis.to_dict()
        return s


def calibrate_exit_threshold(model: ServingModel, x, quantile=0.5):
    """Calibrate an early-exit operating point on a sample batch.

    Returns the confidence threshold at which a ``quantile`` fraction of
    the batch exits at its earliest head (0.5 -> the batch-median
    confidence).  Pure function: the caller decides where the value lives
    (``ChainState.exit_threshold`` via its setter, a benchmark record, a
    scheduler argument) — it must NOT be written into a live model behind
    the caller's back.
    """
    if model.fn_exits is None:
        raise ValueError('model was exported without exit heads')
    _, exits = model.fn_exits(model.params, x)
    conf = exit_confidence(exits[min(exits)])
    return float(jnp.quantile(conf, 1.0 - quantile)) - 1e-6


def export_cnn(params, cfg, *, use_pallas=None, calibrate=None,
               fuse_lowrank=True, select_kernels='model',
               verify=None, tracer=None) -> ServingModel:
    """Compile a (possibly chain-compressed) CNN to the int8 serving path.

    ``calibrate`` (a sample input batch) selects the int8-resident plan:
    static activation scales, requantize epilogues, and cost-selected
    low-rank lowerings — ``select_kernels='model'`` prices fused vs
    chained per factored layer with the analytic ``lowering_costs`` block
    model, ``'measure'`` races both lowerings on the export backend,
    ``'fused'`` forces the one-launch form (``fuse_lowrank=False`` forces
    chained, the benchmark A/B).  ``calibrate=None`` keeps the
    dynamic-scale path (one abs-max per layer per call, fp32 activations
    between layers).

    ``verify`` runs the static analyzer (repro/analysis) over the export:
    ``'strict'`` raises :class:`~repro.analysis.AnalysisError` on any
    error-severity finding, ``'warn'`` only records them.  Either way the
    structured ``AnalysisReport`` lands on ``model.analysis`` and in
    ``model.summary()['analysis']``.  ``None`` (default) skips analysis —
    exports on hot paths (per-test, per-benchmark-variant) stay cheap.

    ``tracer`` (a :class:`repro.obs.Tracer`) records the export timeline
    on the wall clock: an ``export.calibrate`` span around the layer-plan
    compile and, in measure mode, one ``kernel.launch`` span per timed
    lowering rep.
    """
    from repro.obs.trace import as_tracer
    tracer = as_tracer(tracer)
    if verify not in (None, 'strict', 'warn'):
        raise ValueError(f"verify must be None, 'strict' or 'warn', "
                         f'got {verify!r}')
    if use_pallas is None:
        use_pallas = jax.default_backend() == 'tpu'   # kernels are Mosaic-only
    w_bits, a_bits = _serving_bits(cfg)
    qparams = quantize_params_for_serving(params, bits=w_bits)
    plan = None
    if calibrate is not None:
        a_qmax = 2.0 ** (a_bits - 1) - 1.0
        with tracer.span('export.calibrate', track='export',
                         config=cfg.name, select_kernels=select_kernels,
                         batch=int(calibrate.shape[0])):
            plan = _compile_layer_plan(params, cfg, calibrate, a_qmax,
                                       fuse_lowrank=fuse_lowrank,
                                       select_kernels=select_kernels)
        if select_kernels == 'measure' and fuse_lowrank:
            _measure_lowrank_selection(plan, qparams, use_pallas,
                                       tracer=tracer)
        conv_fn, fc_fn, glue_fn, pool_fn = _resident_layers(
            plan, use_pallas, qparams=qparams)
        kw = dict(conv_fn=conv_fn, fc_fn=fc_fn, glue_fn=glue_fn,
                  pool_fn=pool_fn)
    else:
        conv_fn, fc_fn = _serving_layers(use_pallas, a_bits)
        kw = dict(conv_fn=conv_fn, fc_fn=fc_fn)

    @jax.jit
    def fn(p, x):
        return cnn_lib.cnn_forward(p, cfg, x, **kw)

    @jax.jit
    def fn_exits(p, x):
        return cnn_lib.cnn_forward(p, cfg, x, collect_exits=True, **kw)

    stage_fns, stage_exits = (None, ())
    if cfg.exit_stages:
        stage_fns, stage_exits = _make_stage_fns(cfg, kw)
    model = ServingModel(cfg=cfg, params=qparams, fn=fn,
                         fn_exits=fn_exits if cfg.exit_stages else None,
                         plan=plan, stage_fns=stage_fns,
                         stage_exits=stage_exits,
                         backend='pallas' if use_pallas else 'jnp')
    if verify is not None:
        from repro.analysis import check     # lazy: analysis imports core
        model.analysis = check(model, x=calibrate,
                               strict=(verify == 'strict'))
    return model


def export_lm(params, cfg) -> ServingModel:
    """Int8 export for the LM family: ``layers.dense`` consumes the
    {'w_q','scale'} form directly (in-register dequant; Pallas quant_matmul
    on TPU via the launch/steps serve step).  Exit-head serving for LMs
    stays with family.exit_logits."""
    from repro.models import transformer as tfm
    w_bits, _ = _serving_bits(cfg)
    qparams = quantize_params_for_serving(params, bits=w_bits)

    @jax.jit
    def fn(p, tokens):
        return tfm.forward(p, cfg, tokens)

    return ServingModel(cfg=cfg, params=qparams, fn=fn)


# ----------------------------------------------------- serving backends

# {family class: (state, use_pallas, calibrate) -> ServingModel}.  Third-
# party model families register here (mirroring the pass registry in
# core/registry.py) instead of core growing isinstance branches; lookup
# walks the MRO so subclassed families inherit their base family's backend.
_SERVING_BACKENDS: dict[type, Callable] = {}


def register_serving_backend(family_cls: type, backend: Callable) -> None:
    _SERVING_BACKENDS[family_cls] = backend


def serving_backend_for(family) -> Callable:
    for cls in type(family).__mro__:
        if cls in _SERVING_BACKENDS:
            return _SERVING_BACKENDS[cls]
    raise KeyError(
        f'no serving backend registered for family {type(family).__name__} '
        f'(registered: {sorted(c.__name__ for c in _SERVING_BACKENDS)}); '
        f'call export.register_serving_backend(FamilyCls, backend)')


def export_chain(state, *, use_pallas=None, calibrate=None) -> ServingModel:
    """Export a finished ChainState for serving via the family's registered
    backend.  ``calibrate`` (sample inputs) requests the int8-resident
    plan; the chain's E-pass operating point (``state.exit_threshold``)
    is threaded into the served model.

    Backends registered against the original two-arg ``(state,
    use_pallas)`` contract keep working: ``calibrate`` is only forwarded
    (as a keyword) to backends that declare it."""
    import inspect
    backend = serving_backend_for(state.family)
    sig = inspect.signature(backend).parameters
    takes_calibrate = 'calibrate' in sig or any(
        p.kind is p.VAR_KEYWORD for p in sig.values())
    if takes_calibrate:
        model = backend(state, use_pallas, calibrate=calibrate)
    elif calibrate is not None:
        raise TypeError(
            f'serving backend {backend!r} for {type(state.family).__name__} '
            f'does not accept calibrate= (int8-resident export); register '
            f'a backend with a (state, use_pallas, calibrate=None) '
            f'signature')
    else:
        model = backend(state, use_pallas)
    if getattr(state, 'exit_threshold', None) is not None:
        model.exit_threshold = state.exit_threshold
    return model


def _register_builtin_backends():
    from repro.core.family import CNNFamily, LMFamily
    register_serving_backend(
        CNNFamily, lambda state, use_pallas, calibrate=None: export_cnn(
            state.params, state.cfg, use_pallas=use_pallas,
            calibrate=calibrate))
    # the LM backend has no resident plan yet: it deliberately keeps the
    # two-arg signature so export_chain's calibrate guard raises instead of
    # silently ignoring a calibration batch
    register_serving_backend(
        LMFamily, lambda state, use_pallas: export_lm(state.params,
                                                      state.cfg))


_register_builtin_backends()
