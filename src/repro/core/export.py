"""Export pass: compile a finished compression chain into an int8 serving
function running on the Pallas kernels.

``export_chain`` routes through a per-family serving-backend registry
(:func:`register_serving_backend`) — third-party families plug in serving
the same way third-party passes plug into core/registry.py.  Low-rank
factored layers (the 'L' pass) serve as two chained int8 kernel calls.

The chain (e.g. D→P→L→Q→E over the registered passes, core/passes.py /
core/lowrank.py) ends with *fake-quant* params: every
forward still runs fp32 convs/matmuls and recomputes per-channel weight
abs-max scales per call.  This module realizes the Q pass at inference:

1. **Snapshot scales once** — ``quantize_params_for_serving`` converts every
   conv/fc weight to an int8 pytree with static per-out-channel scales
   (weight abs-max is computed exactly once, at export).
2. **Route to kernels** — the jit'd serving function replays the model
   topology via ``cnn_forward``'s layer injection, sending convs through
   the im2col int8 conv (kernels/quant_conv.py) and fcs through the int8
   matmul (kernels/quant_matmul.py), both with fused dequant(+bias)
   epilogues.  Only *activation* scales are computed per call (dynamic
   activation quantization — one per-tensor abs-max per layer, matching the
   QAT grid of core/quantization.fake_quant_act, so exported outputs track
   the fake-quant oracle tightly).
3. **Batched early exit** — the E pass's exit heads are served batched:
   every sample takes its earliest confident exit (softmax confidence over
   a threshold), vectorized with where-masks instead of per-sample control
   flow.

On CPU (``use_pallas=None`` → auto) the serving function runs the pure-jnp
reference path: identical math and static scales, with dense layers on a
real int8 einsum but convs dequantized to an fp32 ``lax.conv``
(ref.quant_conv_ref) — CPU has no int8 conv units, so the CPU win is
limited to eliminating the per-call weight-scale recompute.  The genuine
int8 conv tiles are the TPU path (Mosaic-compiled Pallas kernels).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize_params_for_serving
from repro.kernels import ops
from repro.models import cnn as cnn_lib


def _serving_bits(cfg) -> tuple[int, int]:
    """(w_bits, a_bits) the int8 kernels run at: the chain's QAT bits when
    they fit in int8, else 8 (fp32/no-QAT models serve as W8A8).  Weights
    go down to bits=1 (DoReFa sign*mean, via quantize_weight); activation
    quantization needs >= 2 bits for a nonzero qmax."""
    w_bits = cfg.w_bits if 0 < cfg.w_bits <= 8 else 8
    a_bits = cfg.a_bits if 1 < cfg.a_bits <= 8 else 8
    return w_bits, a_bits


def _serving_layers(use_pallas: bool, a_bits: int):
    """Int8 layer implementations injected into cnn_forward.

    Weight scales live in the params pytree (static); quant here is the
    cfg hook tuple, ignored for weights — that is the QAT/serving split.
    Low-rank factored params ({'u','v'} pairs from family.factorize, each
    half already int8+scale after quantize_params_for_serving) chain two
    kernel calls, mirroring the QAT dispatch in models/cnn.py.
    """
    def conv_fn(p, x, *, stride=1, quant=(0, 0), groups=1):
        del quant
        if 'u' in p:
            h = conv_fn(p['u'], x, stride=stride, groups=groups)
            return conv_fn(p['v'], h)
        return ops.quant_conv_nhwc(x, p['w_q'], p['scale'], p.get('b'),
                                   stride=stride, groups=groups,
                                   a_bits=a_bits, use_pallas=use_pallas)

    def fc_fn(p, x, *, quant=(0, 0)):
        del quant
        if 'u' in p:
            return fc_fn(p['v'], fc_fn(p['u'], x))
        y = ops.quant_dense(x, p['w_q'], p['scale'], a_bits=a_bits,
                            per_row=False, use_pallas=use_pallas)
        return y + p['b'] if 'b' in p else y

    return conv_fn, fc_fn


def early_exit_batch(logits, exits, threshold):
    """Batched early-exit selection: (pred (B,), stage (B,) int32).

    Each sample takes the earliest exit whose softmax confidence clears
    ``threshold``; stage is -1 for samples that ran to the final head.
    Pure jnp (no per-sample control flow) so it jits into the serving fn.
    """
    pred = jnp.argmax(logits, -1)
    stage = jnp.full(pred.shape, -1, jnp.int32)
    taken = jnp.zeros(pred.shape, bool)
    for s in sorted(exits):
        p = jax.nn.softmax(exits[s].astype(jnp.float32), axis=-1)
        take = (p.max(-1) > threshold) & ~taken
        pred = jnp.where(take, jnp.argmax(p, -1), pred)
        stage = jnp.where(take, jnp.int32(s), stage)
        taken |= take
    return pred, stage


@dataclass
class ServingModel:
    """A compiled int8 serving endpoint for a compressed model."""
    cfg: Any
    params: Any                # int8 pytree: {'w_q', 'scale'(, 'b')} leaves
    fn: Callable               # jit'd (params, x) -> logits
    fn_exits: Callable | None = None   # jit'd (params, x) -> (logits, exits)

    def serve(self, x):
        return self.fn(self.params, x)

    def serve_early_exit(self, x, threshold=0.9):
        """(pred, stage) per sample; requires exported exit heads."""
        if self.fn_exits is None:
            raise ValueError('model was exported without exit heads')
        logits, exits = self.fn_exits(self.params, x)
        return early_exit_batch(logits, exits, threshold)


def export_cnn(params, cfg, *, use_pallas=None) -> ServingModel:
    """Compile a (possibly chain-compressed) CNN to the int8 serving path."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == 'tpu'   # kernels are Mosaic-only
    w_bits, a_bits = _serving_bits(cfg)
    qparams = quantize_params_for_serving(params, bits=w_bits)
    conv_fn, fc_fn = _serving_layers(use_pallas, a_bits)

    @jax.jit
    def fn(p, x):
        return cnn_lib.cnn_forward(p, cfg, x, conv_fn=conv_fn, fc_fn=fc_fn)

    @jax.jit
    def fn_exits(p, x):
        return cnn_lib.cnn_forward(p, cfg, x, collect_exits=True,
                                   conv_fn=conv_fn, fc_fn=fc_fn)

    return ServingModel(cfg=cfg, params=qparams, fn=fn,
                        fn_exits=fn_exits if cfg.exit_stages else None)


def export_lm(params, cfg) -> ServingModel:
    """Int8 export for the LM family: ``layers.dense`` consumes the
    {'w_q','scale'} form directly (in-register dequant; Pallas quant_matmul
    on TPU via the launch/steps serve step).  Exit-head serving for LMs
    stays with family.exit_logits."""
    from repro.models import transformer as tfm
    w_bits, _ = _serving_bits(cfg)
    qparams = quantize_params_for_serving(params, bits=w_bits)

    @jax.jit
    def fn(p, tokens):
        return tfm.forward(p, cfg, tokens)

    return ServingModel(cfg=cfg, params=qparams, fn=fn)


# ----------------------------------------------------- serving backends

# {family class: (state, use_pallas) -> ServingModel}.  Third-party model
# families register here (mirroring the pass registry in core/registry.py)
# instead of core growing isinstance branches; lookup walks the MRO so
# subclassed families inherit their base family's backend.
_SERVING_BACKENDS: dict[type, Callable] = {}


def register_serving_backend(family_cls: type, backend: Callable) -> None:
    _SERVING_BACKENDS[family_cls] = backend


def serving_backend_for(family) -> Callable:
    for cls in type(family).__mro__:
        if cls in _SERVING_BACKENDS:
            return _SERVING_BACKENDS[cls]
    raise KeyError(
        f'no serving backend registered for family {type(family).__name__} '
        f'(registered: {sorted(c.__name__ for c in _SERVING_BACKENDS)}); '
        f'call export.register_serving_backend(FamilyCls, backend)')


def export_chain(state, *, use_pallas=None) -> ServingModel:
    """Export a finished ChainState for serving via the family's registered
    backend (old behavior — an isinstance(CNNFamily) branch — is now an
    open registry; see register_serving_backend)."""
    return serving_backend_for(state.family)(state, use_pallas)


def _register_builtin_backends():
    from repro.core.family import CNNFamily, LMFamily
    register_serving_backend(
        CNNFamily, lambda state, use_pallas: export_cnn(
            state.params, state.cfg, use_pallas=use_pallas))
    register_serving_backend(
        LMFamily, lambda state, use_pallas: export_lm(state.params,
                                                      state.cfg))


_register_builtin_backends()
