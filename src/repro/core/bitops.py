"""BitOps / CR cost model — the paper's compression metrics.

Follows the counting of Li et al. (2019) / Liu et al. (2021) as the paper
does: one MAC at w_bits × a_bits precision costs ``w_bits * a_bits`` BitOps;
a float32 MAC costs 32×32.  BitOpsCR = baseline BitOps / compressed BitOps
(expected over early-exit depth for dynamic models).  CR = storage ratio.

Covers both model families:
  * CNNs (paper-native): per-stage conv/fc MACs from CNNConfig + image size,
  * transformers (assigned archs): per-layer MACs from ModelConfig + seq,
    including GQA/MLA attention, MoE (active experts only), RG-LRU and SSD.
"""
from __future__ import annotations

import jax
import numpy as np

FP_BITS = 32


# ---------------------------------------------------------------------- CNNs


def cnn_stage_macs(cfg, image=32):
    """Returns (stem, [per-stage], head, {exit: head_macs}) MAC counts."""
    hw = image
    w0 = cfg.stage_widths[0]
    stem = hw * hw * 9 * cfg.in_channels * w0
    cin = w0
    stages = []
    for s, (n, w) in enumerate(zip(cfg.stage_blocks, cfg.stage_widths)):
        macs = 0
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            hw_out = hw // stride
            if cfg.kind == 'resnet':
                macs += hw_out * hw_out * 9 * cin * w
                macs += hw_out * hw_out * 9 * w * w
                if stride != 1 or cin != w:
                    macs += hw_out * hw_out * cin * w
            elif cfg.kind == 'vgg':
                macs += hw_out * hw_out * 9 * cin * w
            else:                                  # mobilenet
                e = cin * cfg.expand_ratio
                macs += hw * hw * cin * e          # expand 1x1
                macs += hw_out * hw_out * 9 * e    # depthwise
                macs += hw_out * hw_out * e * w    # project 1x1
            hw = hw_out
            cin = w
        stages.append(macs)
    head = cin * cfg.num_classes
    exits = {s: cfg.stage_widths[s] * cfg.num_classes
             for s in range(len(cfg.stage_blocks))}
    return stem, stages, head, exits


def cnn_bitops(cfg, image=32, *, exit_probs=None):
    """Total (expected) BitOps for one image.

    ``exit_probs``: {stage: P(exit at stage)} measured on an eval set; the
    remainder runs the full network.  Exit head costs are charged for every
    evaluated exit (the paper's BitOpsCR-with-threshold accounting).
    """
    w_b = cfg.w_bits or FP_BITS
    a_b = cfg.a_bits or FP_BITS
    stem, stages, head, exit_heads = cnn_stage_macs(cfg, image)
    if not exit_probs:
        return (stem + sum(stages) + head) * w_b * a_b
    total = 0.0
    p_remaining = 1.0
    macs_so_far = stem
    for s in range(len(stages)):
        macs_so_far += stages[s]
        if s in exit_probs:
            macs_so_far += exit_heads[s]           # exit head always evaluated
            p_exit = exit_probs[s]
            total += p_remaining * p_exit * macs_so_far
            p_remaining *= (1.0 - p_exit)
    total += p_remaining * (macs_so_far + head)
    return total * w_b * a_b


# --------------------------------------------------------------- transformers


def lm_layer_macs(cfg, seq: int, *, decode: bool = False, ctx_len: int = 0):
    """Per-layer-kind MAC counts for one sequence (or one decode token)."""
    d = cfg.d_model
    S = 1 if decode else seq
    T = ctx_len if decode else seq
    out = {}
    if cfg.num_heads and not cfg.use_mla:
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        proj = S * d * (H + 2 * K) * hd + S * H * hd * d
        for kind, win in (('global', 0), ('local', cfg.window)):
            Teff = min(T, win) if win else T
            attn = S * Teff * H * hd * 2            # qk + pv
            out[kind] = proj + attn
    if cfg.use_mla:
        H = cfg.num_heads
        dr, dn, dv = cfg.rope_head_dim, cfg.nope_head_dim, cfg.v_head_dim
        r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
        proj = S * d * r_q + S * r_q * H * (dr + dn) + S * d * (r_kv + dr) \
            + S * r_kv * H * (dn + dv) + S * H * dv * d
        if decode:  # absorbed: latent-space attention
            attn = S * H * (dn * r_kv * 2) + S * T * H * (r_kv + dr) \
                + S * T * H * r_kv
            proj = S * d * r_q + S * r_q * H * (dr + dn) \
                + S * d * (r_kv + dr) + S * H * dv * d
        else:
            attn = S * T * H * (dr + dn + dv)
        out['global'] = proj + attn
    if cfg.d_ff:
        out['mlp'] = S * d * cfg.d_ff * 3           # gated: wi, wg, wo
    if cfg.is_moe:
        active = cfg.top_k + cfg.n_shared_experts
        out['moe'] = S * d * cfg.n_experts \
            + S * d * cfg.moe_d_ff * 3 * active
    if cfg.rglru_width:
        w = cfg.rglru_width
        out['recurrent'] = S * (2 * d * w + 2 * w * w + w * d
                                + cfg.rglru_conv * w)
    if cfg.ssm_state:
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state
        h = d_in // cfg.ssm_headdim
        io = S * d * (2 * d_in + 2 * n + h) + S * d_in * d
        if decode:
            ssd = h * cfg.ssm_headdim * n * 2
        else:
            L = min(cfg.ssm_chunk, seq)
            ssd = S * L * n + S * L * h * cfg.ssm_headdim \
                + 2 * S * n * h * cfg.ssm_headdim
        out['ssm'] = io + ssd
    return out


def lm_bitops(cfg, seq: int, *, decode=False, ctx_len=0, exit_probs=None):
    """Total (expected) BitOps for one sequence / one decoded token."""
    w_b = cfg.w_bits or FP_BITS
    a_b = cfg.a_bits or FP_BITS
    macs = lm_layer_macs(cfg, seq, decode=decode, ctx_len=ctx_len)
    S = 1 if decode else seq
    kinds = cfg.layer_kinds()
    per_layer = []
    for i, k in enumerate(kinds):
        m = macs.get(k, macs.get('global', 0))
        if k in ('global', 'local'):
            moe_layer = cfg.is_moe and i >= cfg.first_dense_layers
            m += macs['moe'] if moe_layer else macs.get('mlp', 0)
        elif k == 'recurrent':
            m += macs.get('mlp', 0)
        per_layer.append(m)
    unembed = S * cfg.d_model * cfg.vocab_size
    embed = 0                                       # table lookup
    if not exit_probs:
        return (sum(per_layer) + unembed + embed) * w_b * a_b
    total, p_rem, run = 0.0, 1.0, 0.0
    for i, m in enumerate(per_layer):
        run += m
        if i in exit_probs:
            run += unembed                          # exit head = norm+unembed
            total += p_rem * exit_probs[i] * run
            p_rem *= 1.0 - exit_probs[i]
    total += p_rem * (run + unembed)
    return total * w_b * a_b


# ------------------------------------------------------------------- storage


def param_storage_bits(params, w_bits: int = 0) -> int:
    bits = w_bits or FP_BITS
    return sum(int(np.prod(x.shape)) * bits
               for x in jax.tree_util.tree_leaves(params))


def compression_summary(base_bitops, base_bits, bitops, bits):
    return {'BitOpsCR': base_bitops / max(bitops, 1),
            'CR': base_bits / max(bits, 1)}
