"""Family adapters: uniform Compressible interface over CNNs and LMs.

The compression passes (the registered D/P/Q/E/L and any third-party pass,
see core/registry.py) are family-agnostic; everything model-specific —
loss, physical structured pruning (gather to smaller dense shapes, the
TPU-friendly realization of the paper's channel pruning), student
shrinking, low-rank SVD factorization, exit heads, BitOps — lives here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops as bo
from repro.models import cnn as cnn_lib
from repro.models import transformer as tfm
from repro.models.layers import init_norm, init_dense, dense, rms_norm, unembed, softcap


# ----------------------------------------------------- low-rank SVD helpers


def _svd_split(m, energy, min_rank):
    """Rank-truncated balanced SVD split of a (din, dout) matrix.

    Returns (u (din, r), v (r, dout)) with the smallest r keeping
    ``energy`` of the spectral energy (floored at min_rank), or None when
    no rank saves MACs (r * (din + dout) >= din * dout) — the factorize
    hooks skip such weights rather than inflate them.
    """
    m = np.asarray(m, np.float32)
    din, dout = m.shape
    U, S, Vt = np.linalg.svd(m, full_matrices=False)
    tot = float(np.sum(S ** 2))
    if tot <= 0.0:
        return None
    r = int(np.searchsorted(np.cumsum(S ** 2), energy * tot) + 1)
    r = min(max(r, min_rank), len(S))
    if r * (din + dout) >= din * dout:
        return None
    s = np.sqrt(S[:r])
    return U[:, :r] * s, s[:, None] * Vt[:r]


def _linear_cost(tree) -> float:
    """MAC-proportional weight volume: total size of >=2-D leaves (matmul /
    conv weights; 1-D biases and norm params are free)."""
    return float(sum(x.size for x in jax.tree_util.tree_leaves(tree)
                     if hasattr(x, 'ndim') and x.ndim >= 2))


def _any_factored(tree) -> bool:
    """True if any weight in the pytree is a low-rank {'u','v'} pair.

    Factorization is per-weight (only where a rank saves MACs), so a model
    can be *partially* factored — the prune guards must walk the whole
    tree, not sample one weight per block.
    """
    if isinstance(tree, dict):
        if 'u' in tree and 'v' in tree:
            return True
        return any(_any_factored(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return any(_any_factored(v) for v in tree)
    return False


# ============================================================== CNN family


@dataclass
class CNNFamily:
    data: Any                           # SyntheticImages
    image: int = 32

    # ----- basics
    def init(self, key, cfg):
        return cnn_lib.init_cnn(key, cfg)

    def train_batch(self, key, n):
        return self.data.batch(key, n)

    def logits(self, params, cfg, x, collect_exits=False):
        return cnn_lib.cnn_forward(params, cfg, x, collect_exits=collect_exits)

    def logits_of(self, params, cfg, batch):
        return self.logits(params, cfg, batch[0])

    def default_exit_points(self, cfg):
        n = len(cfg.stage_blocks)
        return tuple(range(max(0, n - 3), n - 1))    # last stages before head

    def exit_loss(self, params, cfg, batch):
        x, y = batch
        _, exits = self.logits(params, cfg, x, collect_exits=True)
        ce = 0.0
        for s, lg in exits.items():
            ce += -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(lg), y[:, None], axis=1))
        return ce / max(len(exits), 1), exits

    def loss(self, params, cfg, batch):
        x, y = batch
        lg = self.logits(params, cfg, x)
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(lg), y[:, None], axis=1))
        return ce, lg

    def eval_batches(self, n, batch, seed=10_000):
        key = jax.random.key(seed)
        return [self.data.batch(jax.random.fold_in(key, i), batch)
                for i in range(n)]

    def accuracy(self, params, cfg, batches):
        hit = tot = 0
        f = jax.jit(lambda p, x: self.logits(p, cfg, x))
        for x, y in batches:
            hit += int(jnp.sum(jnp.argmax(f(params, x), -1) == y))
            tot += int(y.size)
        return hit / tot

    # ----- distillation
    def shrink(self, cfg, factor):
        """Student config: depth-shrink resnet/vgg, width-shrink mobilenet."""
        if cfg.kind == 'mobilenet':
            widths = tuple(max(8, int(w * factor) // 8 * 8)
                           for w in cfg.stage_widths)
            return cfg.replace(name=cfg.name + '-student',
                               stage_widths=widths)
        blocks = tuple(max(1, round(b * factor)) for b in cfg.stage_blocks)
        if blocks == cfg.stage_blocks:               # depth already minimal
            widths = tuple(max(8, int(w * factor) // 4 * 4)
                           for w in cfg.stage_widths)
            return cfg.replace(name=cfg.name + '-student',
                               stage_widths=widths)
        return cfg.replace(name=cfg.name + '-student', stage_blocks=blocks)

    # ----- pruning (physical channel shrink)
    def prune(self, params, cfg, ratio):
        """Prune inner conv channels by L2 importance; returns (params, cfg)."""
        if _any_factored(params):
            raise ValueError(
                'cannot channel-prune a low-rank-factored CNN: apply P '
                'before L (the sequence law orders neuron-granularity '
                'before sub-neuron)')
        params = jax.tree.map(lambda x: x, params)   # shallow copy

        def topk_idx(w, keep):                        # w: (..., C) importance
            imp = np.asarray(jnp.sqrt(jnp.sum(jnp.square(w),
                                              axis=tuple(range(w.ndim - 1)))))
            return np.sort(np.argsort(imp)[::-1][:keep])

        for s, blocks in enumerate(params['stages']):
            for blk in blocks:
                if cfg.kind == 'resnet':
                    C = blk['conv1']['w'].shape[-1]
                    keep = max(4, int(C * (1 - ratio)))
                    idx = topk_idx(blk['conv1']['w'], keep)
                    blk['conv1'] = {'w': blk['conv1']['w'][..., idx],
                                    'b': blk['conv1']['b'][idx]}
                    blk['n1'] = {'scale': blk['n1']['scale'][idx],
                                 'bias': blk['n1']['bias'][idx]}
                    blk['conv2'] = {'w': blk['conv2']['w'][:, :, idx, :],
                                    'b': blk['conv2']['b']}
                elif cfg.kind == 'mobilenet':
                    E = blk['expand']['w'].shape[-1]
                    keep = max(4, int(E * (1 - ratio)))
                    idx = topk_idx(blk['expand']['w'], keep)
                    blk['expand'] = {'w': blk['expand']['w'][..., idx],
                                     'b': blk['expand']['b'][idx]}
                    blk['n1'] = {'scale': blk['n1']['scale'][idx],
                                 'bias': blk['n1']['bias'][idx]}
                    blk['dw'] = {'w': blk['dw']['w'][..., idx],
                                 'b': blk['dw']['b'][idx]}
                    blk['n2'] = {'scale': blk['n2']['scale'][idx],
                                 'bias': blk['n2']['bias'][idx]}
                    blk['project'] = {'w': blk['project']['w'][:, :, idx, :],
                                      'b': blk['project']['b']}
                # vgg handled below (chained)
        if cfg.kind == 'vgg':
            prev_idx = None
            for s, blocks in enumerate(params['stages']):
                for blk in blocks:
                    w = blk['conv1']['w']
                    if prev_idx is not None:
                        w = w[:, :, prev_idx, :]
                    C = w.shape[-1]
                    keep = max(4, int(C * (1 - ratio)))
                    idx = topk_idx(w, keep)
                    blk['conv1'] = {'w': w[..., idx], 'b': blk['conv1']['b'][idx]}
                    blk['n1'] = {'scale': blk['n1']['scale'][idx],
                                 'bias': blk['n1']['bias'][idx]}
                    prev_idx = idx
            params['head'] = {'w': params['head']['w'][prev_idx, :],
                              'b': params['head']['b']}
            widths = tuple(max(4, int(w * (1 - ratio)))
                           for w in cfg.stage_widths)
            cfg = cfg.replace(stage_widths=widths)
        # effective MAC shrink for resnet/mobilenet inner channels: reflect in
        # a pruned-fraction field used by the cost model
        new_cfg = cfg.replace(name=cfg.name) if cfg.kind == 'vgg' else cfg
        return params, new_cfg

    def pruned_bitops_scale(self, ratio, cfg):
        """Fraction of stage MACs remaining after inner-channel pruning."""
        if cfg.kind == 'vgg':
            return 1.0                                # already in cfg widths
        return 1.0 - ratio                            # inner convs dominate

    # ----- low-rank factorization (the 'L' pass's family hook)
    def factorize(self, params, cfg, *, energy=0.95, min_rank=4):
        """SVD-split stage convs and the head fc; returns (params, cfg,
        mac_scale).

        Each conv w (KH,KW,CIN,COUT) flattens to (KH*KW*CIN, COUT) and, when
        a rank r keeping ``energy`` of the spectral energy saves MACs,
        becomes a spatial conv to r channels ('u') chained with a 1x1 conv
        back to COUT ('v') — the forward dispatch lives in models/cnn.py.
        Depthwise convs (grouped; no shared input mixing to factor) and the
        3-channel stem are skipped.  ``mac_scale`` is the stage weight-volume
        ratio — the cost-model multiplier for ``bitops`` (head savings are
        physical in storage but not charged to BitOps: the model scales
        stage MACs only, like pruning).
        """
        params = jax.tree.map(lambda x: x, params)   # shallow copy
        old_cost = _linear_cost(params['stages'])

        def factor_conv(p):
            kh, kw, cin, cout = p['w'].shape
            uv = _svd_split(np.asarray(p['w']).reshape(kh * kw * cin, cout),
                            energy, min_rank)
            if uv is None:
                return p
            u, v = uv
            r = u.shape[-1]
            return {'u': {'w': jnp.asarray(u.reshape(kh, kw, cin, r)),
                          'b': jnp.zeros((r,), p['b'].dtype)},
                    'v': {'w': jnp.asarray(v.reshape(1, 1, r, cout)),
                          'b': p['b']}}

        for blocks in params['stages']:
            for blk in blocks:
                for k, p in list(blk.items()):
                    if (isinstance(p, dict) and 'w' in p
                            and getattr(p['w'], 'ndim', 0) == 4
                            and k != 'dw'):          # depthwise: grouped
                        blk[k] = factor_conv(p)
        uv = _svd_split(params['head']['w'], energy, min_rank)
        if uv is not None:
            u, v = uv
            params['head'] = {'u': {'w': jnp.asarray(u)},
                              'v': {'w': jnp.asarray(v),
                                    'b': params['head']['b']}}
        scale = _linear_cost(params['stages']) / max(old_cost, 1.0)
        return params, cfg, scale

    # ----- early exit
    def add_exits(self, key, params, cfg, stages):
        cfg = cfg.replace(exit_stages=tuple(stages))
        params = dict(params)
        params['exits'] = {}
        for s in stages:
            # read the true (possibly pruned/factored) feature dim off the
            # last block
            blk = params['stages'][s][-1]
            if cfg.kind == 'mobilenet':
                dim = cnn_lib.out_channels(blk['project'])
            elif cfg.kind == 'resnet':
                dim = cnn_lib.out_channels(blk['conv2'])
            else:
                dim = cnn_lib.out_channels(blk['conv1'])
            params['exits'][str(s)] = cnn_lib._fc_init(
                jax.random.fold_in(key, s), dim, cfg.num_classes)
        return params, cfg

    def exit_stats(self, params, cfg, batches, threshold):
        """(accuracy, exit_probs) of the dynamic early-exit model."""
        f = jax.jit(lambda p, x: self.logits(p, cfg, x, collect_exits=True))
        probs = {s: [0, 0] for s in cfg.exit_stages}
        hit = tot = 0
        for x, y in batches:
            final, exits = f(params, x)
            alive = np.ones(y.shape[0], bool)
            pred = np.array(jnp.argmax(final, -1))
            for s in cfg.exit_stages:
                p = np.asarray(jax.nn.softmax(exits[s]))
                conf = p.max(-1) > threshold
                take = alive & conf
                probs[s][0] += int(take.sum())
                probs[s][1] += int(alive.sum())
                pred[take] = p.argmax(-1)[take]
                alive &= ~conf
            hit += int((pred == np.asarray(y)).sum())
            tot += int(y.size)
        exit_probs = {s: (c / max(n, 1)) for s, (c, n) in probs.items()}
        return hit / tot, exit_probs

    # ----- costs
    def bitops(self, cfg, exit_probs=None, mac_scale=1.0):
        """Expected BitOps; ``mac_scale`` multiplies stage MACs (pruning ×
        low-rank — ChainState.mac_scale combines them)."""
        stem, stages, head, exits = bo.cnn_stage_macs(cfg, self.image)
        w_b = cfg.w_bits or bo.FP_BITS
        a_b = cfg.a_bits or bo.FP_BITS
        if not exit_probs:
            return (stem + sum(stages) * mac_scale + head) * w_b * a_b
        total, p_rem, run = 0.0, 1.0, float(stem)
        for s in range(len(stages)):
            run += stages[s] * mac_scale
            if s in exit_probs:
                run += exits[s]
                total += p_rem * exit_probs[s] * run
                p_rem *= 1 - exit_probs[s]
        total += p_rem * (run + head)
        return total * w_b * a_b

    def storage_bits(self, params, cfg):
        return bo.param_storage_bits(params, cfg.w_bits)


# =============================================================== LM family


@dataclass
class LMFamily:
    data: Any                           # SyntheticTokens
    seq: int = 128
    model_cache: dict = field(default_factory=dict)

    def _fwd(self, params, cfg, batch, collect=False):
        return tfm.forward(params, cfg, batch['tokens'],
                           collect_hiddens=collect)

    def init(self, key, cfg):
        return tfm.init_lm(key, cfg)

    def train_batch(self, key, n):
        return self.data.batch(key, n, self.seq)

    def logits_of(self, params, cfg, batch):
        return self._fwd(params, cfg, batch)

    def default_exit_points(self, cfg):
        _, G, _, _ = tfm.layer_groups(cfg)
        return tuple(sorted({G // 3, 2 * G // 3}))

    def exit_loss(self, params, cfg, batch):
        _, exits = self.exit_logits(params, cfg, batch)
        ce = 0.0
        for g, lg in exits.items():
            ce += -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(lg.astype(jnp.float32)),
                batch['labels'][..., None], axis=-1))
        return ce / max(len(exits), 1), exits

    def loss(self, params, cfg, batch):
        lg = self._fwd(params, cfg, batch)
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(lg.astype(jnp.float32)),
            batch['labels'][..., None], axis=-1))
        return ce, lg

    def eval_batches(self, n, batch, seed=10_000):
        key = jax.random.key(seed)
        return [self.data.batch(jax.random.fold_in(key, i), batch, self.seq)
                for i in range(n)]

    def accuracy(self, params, cfg, batches):
        """Next-token top-1 accuracy (the LM analogue of classification acc)."""
        hit = tot = 0
        f = jax.jit(lambda p, b: jnp.argmax(self._fwd(p, cfg, b), -1))
        for b in batches:
            hit += int(jnp.sum(f(params, b) == b['labels']))
            tot += int(b['labels'].size)
        return hit / tot

    def shrink(self, cfg, factor):
        pat = len(cfg.block_pattern)
        n = max(pat, int(round(cfg.num_layers * factor / pat)) * pat)
        return cfg.replace(name=cfg.name + '-student', num_layers=n)

    # ----- pruning: d_ff channels (+ experts for MoE), uniform across layers
    def prune(self, params, cfg, ratio):
        if cfg.is_moe and cfg.n_experts > 2:
            return self._prune_experts(params, cfg, ratio)
        if not cfg.d_ff:
            return params, cfg                       # ssm: P inapplicable
        if _any_factored(params):
            raise ValueError('cannot channel-prune low-rank-factored MLPs: '
                             'apply P before L')
        keep = max(8, int(cfg.d_ff * (1 - ratio)))

        def prune_mlp(mp, stacked):
            wi, wo = mp['wi']['w'], mp['wo']['w']
            wg = mp['wg']['w'] if 'wg' in mp else jnp.zeros_like(wi)
            imp = jnp.sqrt(jnp.sum(jnp.square(wi), axis=-2)
                           + jnp.sum(jnp.square(wg), axis=-2)) \
                * jnp.sqrt(jnp.sum(jnp.square(wo), axis=-1))
            if stacked:
                idx = jnp.argsort(-imp, axis=-1)[..., :keep]   # (G, keep)
                take_col = lambda w: jnp.take_along_axis(       # noqa: E731
                    w, idx[:, None, :], axis=-1)
                take_row = lambda w: jnp.take_along_axis(       # noqa: E731
                    w, idx[..., None], axis=-2)
            else:
                idx = jnp.sort(jnp.argsort(-imp)[:keep])
                take_col = lambda w: w[..., idx]                # noqa: E731
                take_row = lambda w: w[..., idx, :]             # noqa: E731
            out = {'wi': {'w': take_col(wi)}, 'wo': {'w': take_row(wo)}}
            if 'wg' in mp:
                out['wg'] = {'w': take_col(mp['wg']['w'])}
            return out

        new = dict(params)
        new['prefix'] = [dict(lp, mlp=prune_mlp(lp['mlp'], False))
                         if 'mlp' in lp else lp for lp in params['prefix']]
        new['blocks'] = [dict(lp, mlp=prune_mlp(lp['mlp'], True))
                         if 'mlp' in lp else lp for lp in params['blocks']]
        new['tail'] = [dict(lp, mlp=prune_mlp(lp['mlp'], False))
                       if 'mlp' in lp else lp for lp in params['tail']]
        if 'encoder' in params:
            new['encoder'] = dict(
                params['encoder'],
                layers=[dict(lp, mlp=prune_mlp(lp['mlp'], False))
                        for lp in params['encoder']['layers']])
        return new, cfg.replace(d_ff=keep)

    def _prune_experts(self, params, cfg, ratio):
        keep = max(cfg.top_k, int(cfg.n_experts * (1 - ratio)))

        def prune_moe(mp, stacked):
            rw = mp['router']['w']                    # (..., d, E)
            imp = jnp.sqrt(jnp.sum(jnp.square(rw), axis=-2))
            if stacked:
                idx = jnp.argsort(-imp, axis=-1)[..., :keep]    # (G, keep)
                r = jnp.take_along_axis(rw, idx[:, None, :], axis=-1)
                tk = lambda w: jnp.take_along_axis(             # noqa: E731
                    w, idx[:, :, None, None], axis=1)
            else:
                idx = jnp.sort(jnp.argsort(-imp)[:keep])
                r = rw[..., idx]
                tk = lambda w: w[idx]                           # noqa: E731
            out = dict(mp, router={'w': r}, wi=tk(mp['wi']), wg=tk(mp['wg']),
                       wo=tk(mp['wo']))
            return out

        new = dict(params)
        for grp in ('prefix', 'blocks', 'tail'):
            new[grp] = [dict(lp, moe=prune_moe(lp['moe'], grp == 'blocks'))
                        if 'moe' in lp else lp for lp in params[grp]]
        return new, cfg.replace(n_experts=keep)

    # ----- low-rank factorization (the 'L' pass's family hook)
    def factorize(self, params, cfg, *, energy=0.95, min_rank=8):
        """SVD-split dense MLP weights (wi/wg/wo); returns (params, cfg,
        mac_scale).

        Unstacked layers (prefix/tail/encoder) factor per-weight; the
        scan-stacked block group (G, d, f) factors with one shared rank
        (max over groups) so the stacked pytree stays rectangular — the
        per-layer slices dispatch through ``layers.dense``'s u/v path.
        MoE expert tensors and attention projections are left alone.
        ``mac_scale`` is the whole-tree weight-volume ratio, a
        MAC-proportional proxy applied multiplicatively by ``bitops``
        (attention-score MACs make it slightly conservative).
        """
        old_cost = _linear_cost(params)

        def factor_w(wp):                            # {'w': (d,f)} -> u/v
            uv = _svd_split(wp['w'], energy, min_rank)
            if uv is None:
                return wp
            u, v = uv
            return {'u': {'w': jnp.asarray(u)}, 'v': {'w': jnp.asarray(v)}}

        def factor_stacked(wp):                      # {'w': (G,d,f)}
            w = np.asarray(wp['w'], np.float32)
            G, d, f = w.shape
            U, S, Vt = np.linalg.svd(w, full_matrices=False)
            tot = np.sum(S ** 2, axis=-1, keepdims=True)
            if not np.all(tot > 0):
                return wp
            cum = np.cumsum(S ** 2, axis=-1)
            ranks = (cum < energy * tot).sum(axis=-1) + 1   # per-group rank
            r = int(min(max(int(ranks.max()), min_rank), S.shape[-1]))
            if r * (d + f) >= d * f:
                return wp
            s = np.sqrt(S[:, :r])
            u = U[:, :, :r] * s[:, None, :]
            v = s[:, :, None] * Vt[:, :r, :]
            return {'u': {'w': jnp.asarray(u)}, 'v': {'w': jnp.asarray(v)}}

        def factor_mlp(mp, stacked):
            fn = factor_stacked if stacked else factor_w
            return {k: fn(wp) if k in ('wi', 'wg', 'wo') else wp
                    for k, wp in mp.items()}

        new = dict(params)
        for grp in ('prefix', 'blocks', 'tail'):
            new[grp] = [dict(lp, mlp=factor_mlp(lp['mlp'], grp == 'blocks'))
                        if 'mlp' in lp else lp for lp in params[grp]]
        if 'encoder' in params:
            new['encoder'] = dict(
                params['encoder'],
                layers=[dict(lp, mlp=factor_mlp(lp['mlp'], False))
                        if 'mlp' in lp else lp
                        for lp in params['encoder']['layers']])
        scale = _linear_cost(new) / max(old_cost, 1.0)
        return new, cfg, scale

    # ----- early exit: heads after scan groups
    def add_exits(self, key, params, cfg, groups):
        params = dict(params)
        params['exit_heads'] = {
            str(g): {'norm': init_norm(cfg.d_model, jnp.dtype(cfg.dtype)),
                     'adapter': init_dense(jax.random.fold_in(key, g),
                                           cfg.d_model, cfg.d_model,
                                           dtype=jnp.dtype(cfg.dtype))}
            for g in groups}
        return params, cfg.replace(exit_layers=tuple(groups))

    def exit_logits(self, params, cfg, batch):
        lg, hiddens = self._fwd(params, cfg, batch, collect=True)
        quant = (cfg.w_bits, cfg.a_bits)
        out = {}
        for g_str, hp in params.get('exit_heads', {}).items():
            g = int(g_str)
            h = hiddens[g]
            h = rms_norm(hp['norm'], h + dense(hp['adapter'], h, quant=quant),
                         cfg.norm_eps)
            elg = unembed(params.get('unembed', params['embed']), h,
                          quant=quant)
            out[g] = softcap(elg, cfg.logit_softcap)
        return lg, out

    def exit_stats(self, params, cfg, batches, threshold):
        f = jax.jit(lambda p, b: self.exit_logits(p, cfg, b))
        probs = {g: [0, 0] for g in cfg.exit_layers}
        hit = tot = 0
        for b in batches:
            final, exits = f(params, b)
            y = np.asarray(b['labels']).reshape(-1)
            alive = np.ones(y.shape, bool)
            pred = np.array(jnp.argmax(final, -1)).reshape(-1)
            for g in sorted(cfg.exit_layers):
                p = np.asarray(jax.nn.softmax(
                    exits[g].astype(jnp.float32))).reshape(-1, cfg.vocab_size)
                conf = p.max(-1) > threshold
                take = alive & conf
                probs[g][0] += int(take.sum())
                probs[g][1] += int(alive.sum())
                pred[take] = p.argmax(-1)[take]
                alive &= ~conf
            hit += int((pred == y).sum())
            tot += int(y.size)
        return hit / tot, {g: c / max(n, 1) for g, (c, n) in probs.items()}

    # ----- costs
    def bitops(self, cfg, exit_probs=None, mac_scale=1.0):
        # exit indices are scan-group indices -> convert to layer indices
        ep = None
        if exit_probs:
            P = len(cfg.block_pattern)
            ep = {cfg.first_dense_layers + (g + 1) * P - 1: p
                  for g, p in exit_probs.items()}
        # pruning is physical in cfg (d_ff / n_experts); mac_scale carries
        # the low-rank weight-volume ratio, which cfg cannot express
        return bo.lm_bitops(cfg, self.seq, exit_probs=ep) * mac_scale

    def storage_bits(self, params, cfg):
        return bo.param_storage_bits(params, cfg.w_bits)
