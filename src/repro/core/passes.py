"""Compression passes as standard building blocks (the paper's Fig. 1).

Each pass declares static metadata (kind: static/dynamic, granularity:
architecture/neuron/sub-neuron — the two axes the paper's sequence law is
stated in), a *typed* hyperparameter dataclass, and a transform
``fn(state, hp, trainer) -> state``; all of it is packaged as a
:class:`repro.core.registry.CompressionPass` and registered in the global
registry.  Fine-tuning after every pass uses 1/10 of the initial LR,
matching the paper's protocol.

Migration note (old API → registry): ``PASSES`` used to be a closed module
dict of exactly D/P/Q/E.  It is now a live read-only *view* of
``core.registry`` — existing ``PASSES['Q'].apply(state, {...}, trainer)``
call sites keep working (dict hps are coerced to the typed dataclass), and
newly registered passes (e.g. low-rank 'L' from core/lowrank.py, or any
third-party pass) appear in it automatically.  New code should use
``registry.get_pass`` / ``chain.Pipeline`` directly.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import registry


# ------------------------------------------------------------------ trainer


def mask_like(params, select: Callable[[str], bool]):
    """0/1 mask pytree: 1 where the top-level key satisfies `select`."""
    return {k: jax.tree.map(lambda x: jnp.ones((), x.dtype) * float(select(k)),
                            v) for k, v in params.items()}


@dataclass
class Trainer:
    batch: int = 64
    steps: int = 300
    lr: float = 1e-3
    eval_n: int = 4
    eval_batch: int = 256
    weight_decay: float = 1e-4
    seed: int = 0

    def fit(self, family, cfg, params, *, loss_fn=None, lr=None, steps=None,
            train_keys=None, seed=None):
        """SGD loop; train_keys restricts training to those top-level keys."""
        from repro.optim import adamw, apply_updates, clip_by_global_norm
        loss_fn = loss_fn or family.loss
        lr = self.lr if lr is None else lr
        steps = self.steps if steps is None else steps
        opt = adamw(lr, weight_decay=self.weight_decay)
        opt_state = opt.init(params)
        mask = None
        if train_keys is not None:
            mask = mask_like(params, lambda k: k in train_keys)

        @jax.jit
        def step(params, opt_state, batch):
            (l, _), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
            grads, _ = clip_by_global_norm(grads, 1.0)
            if mask is not None:
                grads = jax.tree.map(lambda g, m: g * m, grads, mask)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, l

        key = jax.random.key(self.seed if seed is None else seed)
        last = None
        for i in range(steps):
            batch = family.train_batch(jax.random.fold_in(key, i), self.batch)
            params, opt_state, last = step(params, opt_state, batch)
        return params, float(last) if last is not None else None

    def evaluate(self, family, cfg, params):
        return family.accuracy(params, cfg,
                               family.eval_batches(self.eval_n,
                                                   self.eval_batch))


# -------------------------------------------------------------- chain state


@dataclass
class ChainState:
    family: Any
    cfg: Any
    params: Any
    key: Any
    base_bitops: float = 0.0
    base_bits: float = 0.0
    prune_scale: float = 1.0       # stage-MAC multiplier from pruning
    lowrank_scale: float = 1.0     # stage-MAC multiplier from factorization
    exit_probs: dict | None = None
    exit_threshold: float | None = None   # E's operating point, reused by Q
    dyn_accuracy: float | None = None
    history: list = field(default_factory=list)

    @property
    def mac_scale(self) -> float:
        """Combined stage-MAC multiplier for the BitOps cost model."""
        return self.prune_scale * self.lowrank_scale

    def metrics(self, trainer, label):
        acc = (self.dyn_accuracy if self.dyn_accuracy is not None
               else trainer.evaluate(self.family, self.cfg, self.params))
        bops = self.family.bitops(self.cfg, self.exit_probs, self.mac_scale)
        bits = self.family.storage_bits(self.params, self.cfg)
        rec = {'pass': label, 'acc': acc,
               'BitOpsCR': self.base_bitops / max(bops, 1),
               'CR': self.base_bits / max(bits, 1)}
        self.history.append(rec)
        return rec


def init_chain_state(family, cfg, key, trainer, *, pretrain_steps=None):
    """Train the original model — the paper's baseline."""
    params = family.init(key, cfg)
    params, _ = trainer.fit(family, cfg, params, steps=pretrain_steps)
    st = ChainState(family=family, cfg=cfg, params=params,
                    key=jax.random.fold_in(key, 777))
    st.base_bitops = family.bitops(cfg)
    st.base_bits = family.storage_bits(params, cfg)
    st.metrics(trainer, 'baseline')
    return st


# --------------------------------------------------- typed hyperparameters


@dataclass(frozen=True)
class DistillHP:
    factor: float = 0.5      # student size factor (depth or width)
    temp: float = 2.0        # KD temperature
    alpha: float = 0.5       # KL weight vs. CE


@dataclass(frozen=True)
class PruneHP:
    ratio: float = 0.3       # fraction of channels removed


@dataclass(frozen=True)
class QuantHP:
    w_bits: int = 8
    a_bits: int = 8


@dataclass(frozen=True)
class EarlyExitHP:
    stages: tuple | None = None    # None = family.default_exit_points
    threshold: float = 0.9         # softmax-confidence exit threshold


# ------------------------------------------------------------------- passes


def _distill(state: ChainState, hp: DistillHP, trainer: Trainer) -> ChainState:
    # T=2, alpha=0.5 defaults: at T=4 the T^2-scaled KL dominates the
    # clipped gradient and stalls student training (measured; see
    # EXPERIMENTS.md §Paper-results tuning note)
    temp, alpha = hp.temp, hp.alpha
    fam, t_cfg, t_params = state.family, state.cfg, state.params
    s_cfg = fam.shrink(t_cfg, hp.factor)
    s_params = fam.init(jax.random.fold_in(state.key, 1), s_cfg)

    def kd_loss(p, cfg, batch):
        ce, s_logits = fam.loss(p, cfg, batch)
        t_logits = jax.lax.stop_gradient(fam.logits_of(t_params, t_cfg, batch))
        kl = jnp.mean(jnp.sum(
            jax.nn.softmax(t_logits / temp)
            * (jax.nn.log_softmax(t_logits / temp)
               - jax.nn.log_softmax(s_logits / temp)), axis=-1)) * temp ** 2
        return alpha * kl + (1 - alpha) * ce, s_logits

    # a student is trained from scratch: give it the full (pretrain-scale)
    # budget, like the paper's 200-epoch student training
    s_params, _ = trainer.fit(fam, s_cfg, s_params, loss_fn=kd_loss,
                              steps=trainer.steps * 3,
                              seed=int(jax.random.randint(
                                  state.key, (), 0, 2**31 - 1)))
    new = replace(state, cfg=s_cfg, params=s_params,
                  key=jax.random.fold_in(state.key, 2),
                  exit_probs=None, dyn_accuracy=None, prune_scale=1.0,
                  lowrank_scale=1.0)
    return new


def _prune(state: ChainState, hp: PruneHP, trainer: Trainer) -> ChainState:
    fam = state.family
    params, cfg = fam.prune(state.params, state.cfg, hp.ratio)
    params, _ = trainer.fit(fam, cfg, params, lr=trainer.lr / 10)
    scale = state.prune_scale
    if hasattr(fam, 'pruned_bitops_scale'):
        scale *= fam.pruned_bitops_scale(hp.ratio, cfg)
    return replace(state, cfg=cfg, params=params, prune_scale=scale,
                   key=jax.random.fold_in(state.key, 3),
                   exit_probs=None, dyn_accuracy=None)


def _quantize(state: ChainState, hp: QuantHP, trainer: Trainer) -> ChainState:
    cfg = state.cfg.replace(w_bits=hp.w_bits, a_bits=hp.a_bits)
    params, _ = trainer.fit(state.family, cfg, state.params,
                            lr=trainer.lr / 10)
    new = replace(state, cfg=cfg, params=params,
                  key=jax.random.fold_in(state.key, 4))
    if new.exit_probs is not None:
        # re-measure dynamic stats under quantized compute, at the SAME
        # operating point E established (state.exit_threshold) — Q has no
        # threshold hp of its own, so it cannot silently move it
        thr = (state.exit_threshold if state.exit_threshold is not None
               else 0.9)
        acc, probs = state.family.exit_stats(
            params, cfg, state.family.eval_batches(trainer.eval_n,
                                                   trainer.eval_batch), thr)
        new = replace(new, exit_probs=probs, dyn_accuracy=acc)
    return new


def _early_exit(state: ChainState, hp: EarlyExitHP,
                trainer: Trainer) -> ChainState:
    fam = state.family
    stages = hp.stages
    if stages is None:
        stages = fam.default_exit_points(state.cfg)
    params, cfg = fam.add_exits(jax.random.fold_in(state.key, 5),
                                state.params, state.cfg, stages)
    # paper insight (Sec 3.1.3/3.1.6): exit heads learn from the *student's
    # own body*; train heads only, body frozen, full LR.
    exit_key = 'exits' if 'exits' in params else 'exit_heads'
    loss_fn = getattr(fam, 'exit_loss', None)
    params, _ = trainer.fit(fam, cfg, params, loss_fn=loss_fn,
                            train_keys={exit_key})
    acc, probs = fam.exit_stats(
        params, cfg, fam.eval_batches(trainer.eval_n, trainer.eval_batch),
        hp.threshold)
    return replace(state, cfg=cfg, params=params, exit_probs=probs,
                   exit_threshold=hp.threshold,
                   dyn_accuracy=acc, key=jax.random.fold_in(state.key, 6))


# -------------------------------------------------------------- registration


registry.register(registry.CompressionPass(
    'D', 'distillation', 'static', 'architecture', DistillHP, _distill))
registry.register(registry.CompressionPass(
    'P', 'pruning', 'static', 'neuron', PruneHP, _prune))
registry.register(registry.CompressionPass(
    'Q', 'quantization', 'static', 'sub-neuron', QuantHP, _quantize))
registry.register(registry.CompressionPass(
    'E', 'early-exit', 'dynamic', 'architecture', EarlyExitHP, _early_exit))


class _RegistryView(Mapping):
    """Read-only mapping view of the live registry (old ``PASSES`` API)."""

    def __getitem__(self, key):
        return registry.get_pass(key)

    def __iter__(self):
        return iter(registry.registered_keys())

    def __len__(self):
        return len(registry.registered_keys())


#: Deprecated alias — a live view of ``core.registry`` (see module docstring).
PASSES = _RegistryView()
