"""Fixed-point uniform quantization-aware training (paper's Q pass).

Follows DoReFa-style fixed-point uniform QAT (Zhou et al., 2016): symmetric
per-channel weight quantization + unsigned activation quantization after a
learned-free clip, with straight-through estimators.  This module is pure
jnp — it is both the math used inside the models (fake-quant hook on every
matmul) and the oracle for the Pallas ``fake_quant`` / ``quant_matmul``
kernels.

The actual *pass* object (QuantizationPass) lives in core/passes.py; it sets
``cfg.w_bits / cfg.a_bits`` and runs QAT fine-tuning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _ste(x_q: jax.Array, x: jax.Array) -> jax.Array:
    """Straight-through estimator: forward x_q, gradient of identity."""
    return x + jax.lax.stop_gradient(x_q - x)


# Counts weight abs-max (scale) computations, including under tracing.  The
# export tests use it to prove the exported serving function recomputes NO
# weight scales per call: tracing the serving fn must leave it unchanged,
# while tracing a fake-quant forward bumps it once per weight.
WEIGHT_SCALE_COMPUTATIONS = [0]


def quantize_weight(w: jax.Array, bits: int, *, axis=-1):
    """Symmetric per-channel int quantization. Returns (int_values, scale).

    ``axis`` is the axis (or tuple of axes) that keep their own scale
    (None = per-tensor).  bits=1 follows DoReFa binary weights
    (sign * mean|w|).  This is the single weight quantizer — QAT
    (fake_quant_weight) and serving export (quantize_params_for_serving,
    ops.prequantize_weight) all route here, so grids cannot drift.
    """
    WEIGHT_SCALE_COMPUTATIONS[0] += 1
    if axis is None:
        red = None
    else:
        kept = {a % w.ndim for a in
                ((axis,) if isinstance(axis, int) else tuple(axis))}
        red = tuple(i for i in range(w.ndim) if i not in kept)
    if bits == 1:
        scale = jnp.mean(jnp.abs(w), axis=red, keepdims=True)
        q = jnp.sign(w)
        q = jnp.where(q == 0, 1.0, q)
        return q.astype(jnp.int8), scale
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=red is not None)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32), scale


def fake_quant_weight(w: jax.Array, bits: int, *, axis: int | None = -1,
                      use_kernel: bool | None = None) -> jax.Array:
    """Quantize->dequantize with STE (QAT forward for weights).

    On accelerators the 2D last-axis case routes to the fused Pallas
    fake-quant kernel (kernels/fake_quant.py — one HBM pass instead of
    XLA's materialized abs/max/round chain); the STE makes the kernel's
    gradient irrelevant (stop_gradient), so no custom VJP is needed.  CPU
    (and odd shapes/axes, and the bits=1 DoReFa grid) stay on pure jnp.
    """
    if bits <= 0 or bits >= 32:
        return w
    if use_kernel is None:
        use_kernel = (jax.default_backend() == 'tpu' and w.ndim == 2
                      and bits > 1 and axis in (-1, 1))
    if use_kernel:
        return _kernel_fake_quant_ste(w, bits)
    q, scale = quantize_weight(w, bits, axis=axis)
    return _ste(q.astype(w.dtype) * scale.astype(w.dtype), w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _kernel_fake_quant_ste(w, bits):
    # STE via custom_vjp: autodiff never traces into pallas_call
    from repro.kernels.ops import fake_quant as _kernel_fq
    return _kernel_fq(w, bits).astype(w.dtype)


def _kfq_fwd(w, bits):
    return _kernel_fake_quant_ste(w, bits), None


def _kfq_bwd(bits, _res, g):
    return (g,)


_kernel_fake_quant_ste.defvjp(_kfq_fwd, _kfq_bwd)


def fake_quant_act(x: jax.Array, bits: int, *, amax: float | None = None) -> jax.Array:
    """Activation fake-quant: symmetric uniform with running-free abs-max clip.

    Per-tensor dynamic scale (abs-max of the current batch) — matches the
    hardware-friendly 'fixed-point uniform' choice in the paper; stop-gradient
    on the scale keeps QAT stable.
    """
    if bits <= 0 or bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    s = jnp.max(jnp.abs(x)) if amax is None else jnp.asarray(amax, x.dtype)
    s = jax.lax.stop_gradient(jnp.maximum(s, 1e-8)) / qmax
    xq = jnp.clip(jnp.round(x / s), -qmax - 1, qmax) * s
    return _ste(xq.astype(x.dtype), x)


def quantize_params_for_serving(params, bits: int = 8):
    """Convert every matmul/conv weight to int8 + per-out-channel scales.

    The serving-side realization of the paper's Q pass: weights are stored
    (and read from HBM) as int8, halving the weight-streaming bytes that
    dominate memory-bound decode.  ``layers.dense`` recognizes the
    {'w_q','scale'} form and dequantizes in-register; the exported CNN path
    (core/export.py) feeds the int8 form directly to the Pallas
    quant_matmul/quant_conv kernels.  Covered weights: 2D dense (d,f),
    scan-stacked 3D (G,d,f), 4D NHWC conv (KH,KW,CIN,COUT) — conv scales
    are stored flat (COUT,) as the quant_conv kernel consumes them.
    Embedding tables (lookups), norm scales, and recurrent conv1d taps
    (under the 'conv' key — elementwise, not matmuls) are left untouched.
    """
    def quant(v, flat_scale=False):
        # one quantizer (quantize_weight) for QAT and serving, so the
        # bits=1 DoReFa branch and clip conventions cannot drift
        v = v.astype(jnp.float32)
        if flat_scale:               # conv (KH,KW,CIN,COUT): (COUT,) scales
            q, scale = quantize_weight(v, bits, axis=-1)
            scale = scale.reshape(-1)
        else:                        # dense (d,f) / stacked (G,d,f): keep
            kept = tuple(i for i in range(v.ndim) if i != v.ndim - 2)
            q, scale = quantize_weight(v, bits, axis=kept)
        return q.astype(jnp.int8), scale.astype(jnp.float32)

    def convert(node, name=''):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                # matmul weights: 2D (d,f) or scan-stacked 3D (G,d,f)
                if name != 'conv' and k == 'w' and hasattr(v, 'ndim') \
                        and v.ndim in (2, 3):
                    q, s = quant(v)
                    out['w_q'], out['scale'] = q, s
                # NHWC conv weights (KH,KW,CIN,COUT): flat (COUT,) scales
                elif k == 'w' and hasattr(v, 'ndim') and v.ndim == 4:
                    q, s = quant(v, flat_scale=True)
                    out['w_q'], out['scale'] = q, s
                # MoE expert weights: (E,d,f) or stacked (G,E,d,f)
                elif k in ('wi', 'wg', 'wo') and hasattr(v, 'ndim') \
                        and getattr(v, 'ndim', 0) in (3, 4) \
                        and not isinstance(v, dict):
                    q, s = quant(v)
                    out[k] = {'w_q': q, 'scale': s}
                else:
                    out[k] = convert(v, k)
            return out
        if isinstance(node, list):
            return [convert(v, name) for v in node]
        if isinstance(node, tuple):
            return tuple(convert(v, name) for v in node)
        return node

    return convert(params)


def quantized_params_bits(params, bits: int) -> int:
    """Total storage bits for a params pytree at `bits` per weight."""
    leaves = jax.tree_util.tree_leaves(params)
    n = sum(x.size for x in leaves)
    return n * bits
