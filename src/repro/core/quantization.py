"""Fixed-point uniform quantization-aware training (paper's Q pass).

Follows DoReFa-style fixed-point uniform QAT (Zhou et al., 2016): symmetric
per-channel weight quantization + unsigned activation quantization after a
learned-free clip, with straight-through estimators.  This module is pure
jnp — it is both the math used inside the models (fake-quant hook on every
matmul) and the oracle for the Pallas ``fake_quant`` / ``quant_matmul``
kernels.

The actual *pass* object (QuantizationPass) lives in core/passes.py; it sets
``cfg.w_bits / cfg.a_bits`` and runs QAT fine-tuning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _ste(x_q: jax.Array, x: jax.Array) -> jax.Array:
    """Straight-through estimator: forward x_q, gradient of identity."""
    return x + jax.lax.stop_gradient(x_q - x)


def quantize_weight(w: jax.Array, bits: int, *, axis: int | None = -1):
    """Symmetric per-channel int quantization. Returns (int_values, scale).

    ``axis`` is the output-channel axis that gets its own scale
    (None = per-tensor).  bits=1 follows DoReFa binary weights
    (sign * mean|w|).
    """
    if bits == 1:
        scale = jnp.mean(jnp.abs(w), axis=None if axis is None else tuple(
            i for i in range(w.ndim) if i != (axis % w.ndim)), keepdims=True)
        q = jnp.sign(w)
        q = jnp.where(q == 0, 1.0, q)
        return q.astype(jnp.int8), scale
    qmax = 2.0 ** (bits - 1) - 1.0
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
        amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32), scale


def fake_quant_weight(w: jax.Array, bits: int, *, axis: int | None = -1) -> jax.Array:
    """Quantize->dequantize with STE (QAT forward for weights)."""
    if bits <= 0 or bits >= 32:
        return w
    q, scale = quantize_weight(w, bits, axis=axis)
    return _ste(q.astype(w.dtype) * scale.astype(w.dtype), w)


def fake_quant_act(x: jax.Array, bits: int, *, amax: float | None = None) -> jax.Array:
    """Activation fake-quant: symmetric uniform with running-free abs-max clip.

    Per-tensor dynamic scale (abs-max of the current batch) — matches the
    hardware-friendly 'fixed-point uniform' choice in the paper; stop-gradient
    on the scale keeps QAT stable.
    """
    if bits <= 0 or bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    s = jnp.max(jnp.abs(x)) if amax is None else jnp.asarray(amax, x.dtype)
    s = jax.lax.stop_gradient(jnp.maximum(s, 1e-8)) / qmax
    xq = jnp.clip(jnp.round(x / s), -qmax - 1, qmax) * s
    return _ste(xq.astype(x.dtype), x)


def quantize_params_for_serving(params, bits: int = 8):
    """Convert every matmul weight to int8 + per-out-channel scales.

    The serving-side realization of the paper's Q pass: weights are stored
    (and read from HBM) as int8, halving the weight-streaming bytes that
    dominate memory-bound decode.  ``layers.dense`` recognizes the
    {'w_q','scale'} form and dequantizes in-register (on TPU the
    kernels/quant_matmul Pallas kernel consumes the int8 form directly).
    Embedding tables (lookups) and norm scales are left untouched.
    """
    qmax = 2.0 ** (bits - 1) - 1.0

    def quant(v):
        # per-(layer, out-channel) scales: reduce the contraction dim only
        amax = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-2,
                       keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / qmax
        q = jnp.clip(jnp.round(v.astype(jnp.float32) / scale),
                     -qmax - 1, qmax).astype(jnp.int8)
        return q, scale.astype(jnp.float32)

    def convert(node, name=''):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                # matmul weights: 2D (d,f) or scan-stacked 3D (G,d,f)
                if name != 'conv' and k == 'w' and hasattr(v, 'ndim') \
                        and v.ndim in (2, 3):
                    q, s = quant(v)
                    out['w_q'], out['scale'] = q, s
                # MoE expert weights: (E,d,f) or stacked (G,E,d,f)
                elif k in ('wi', 'wg', 'wo') and hasattr(v, 'ndim') \
                        and getattr(v, 'ndim', 0) in (3, 4) \
                        and not isinstance(v, dict):
                    q, s = quant(v)
                    out[k] = {'w_q': q, 'scale': s}
                else:
                    out[k] = convert(v, k)
            return out
        if isinstance(node, list):
            return [convert(v, name) for v in node]
        if isinstance(node, tuple):
            return tuple(convert(v, name) for v in node)
        return node

    return convert(params)


def quantized_params_bits(params, bits: int) -> int:
    """Total storage bits for a params pytree at `bits` per weight."""
    leaves = jax.tree_util.tree_leaves(params)
    n = sum(x.size for x in leaves)
    return n * bits
