"""Synthetic-but-learnable datasets (the container has no CIFAR/SVHN offline).

* Images — class-conditional smooth templates + jitter + noise: a small CNN
  separates classes only by learning the templates, so accuracy responds to
  capacity/compression the same way a natural dataset's does (relative
  ordering is what the paper's claims are about).
* Tokens — a Zipf-unigram + class-dependent-bigram language: cross-entropy
  improves with model capacity, giving the LM chain a learnable target.

Both are deterministic given seed, sharded by host for multi-pod input
(each host generates its slice — a real data pipeline would read shards;
the determinism is what the straggler-mitigation reassignment relies on).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticImages:
    num_classes: int = 10
    size: int = 32
    channels: int = 3
    seed: int = 0
    difficulty: float = 0.8      # noise/signal ratio; higher = harder

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        t = rng.normal(size=(self.num_classes, self.size, self.size,
                             self.channels)).astype(np.float32)
        # smooth the templates so convs with small receptive fields can learn
        for _ in range(2):
            t = (t + np.roll(t, 1, 1) + np.roll(t, -1, 1)
                 + np.roll(t, 1, 2) + np.roll(t, -1, 2)) / 5.0
        self.templates = jnp.asarray(t / t.std())

    def batch(self, key, n):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        y = jax.random.randint(k1, (n,), 0, self.num_classes)
        shift = jax.random.randint(k2, (n, 2), -3, 4)
        base = self.templates[y]
        base = jax.vmap(lambda img, s: jnp.roll(img, s, axis=(0, 1)))(base, shift)
        noise = jax.random.normal(k3, base.shape) * self.difficulty
        scale = 1.0 + 0.1 * jax.random.normal(k4, (n, 1, 1, 1))
        return base * scale + noise, y


@dataclass
class SyntheticTokens:
    vocab: int
    seed: int = 0
    n_rules: int = 64            # deterministic bigram successor rules

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipf unigram distribution
        ranks = np.arange(1, self.vocab + 1)
        p = 1.0 / ranks
        self.unigram = jnp.asarray(p / p.sum(), dtype=jnp.float32)
        self.rule_src = jnp.asarray(
            rng.choice(self.vocab, self.n_rules, replace=False))
        self.rule_dst = jnp.asarray(rng.choice(self.vocab, self.n_rules))

    def batch(self, key, n, seq):
        k1, k2 = jax.random.split(key)
        toks = jax.random.categorical(
            k1, jnp.log(self.unigram)[None, None, :], shape=(n, seq + 1))
        # apply bigram rules: if t[i] is a rule source, t[i+1] = rule dst
        # (deterministic structure a model can learn)
        match = (toks[:, :-1, None] == self.rule_src[None, None, :])
        dst = jnp.einsum('bsr,r->bs', match.astype(jnp.int32),
                         self.rule_dst.astype(jnp.int32))
        hit = match.any(-1)
        toks = toks.at[:, 1:].set(jnp.where(hit, dst, toks[:, 1:]))
        return {'tokens': toks[:, :-1], 'labels': toks[:, 1:]}


def image_batches(ds: SyntheticImages, batch, steps, seed=0):
    key = jax.random.key(seed)
    for i in range(steps):
        yield ds.batch(jax.random.fold_in(key, i), batch)


def lm_batches(ds: SyntheticTokens, batch, seq, steps, seed=0,
               host_id=0, num_hosts=1):
    """Host-sharded deterministic stream: host h takes fold_in(step, h)."""
    key = jax.random.key(seed)
    for i in range(steps):
        k = jax.random.fold_in(jax.random.fold_in(key, i), host_id)
        yield ds.batch(k, batch // num_hosts, seq)
