from repro.data.synthetic import (SyntheticImages, SyntheticTokens,
                                  lm_batches, image_batches)  # noqa: F401
