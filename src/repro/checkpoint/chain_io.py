"""ChainState persistence: the compression chain survives preemption, and
the serving model registry loads finished chains from disk.

Built on :mod:`repro.checkpoint.manager` (sharded atomic npz steps): the
params pytree — including low-rank ``{'u','v'}`` factored weights and
pruned shapes — goes through ``save_checkpoint``; everything the arrays
cannot carry rides in a JSON sidecar per step:

* the cfg dataclass (class path + fields, tuples restored on load),
* the chain scalars (``exit_threshold``, ``prune_scale``,
  ``lowrank_scale``, ``base_bitops``, ``base_bits``, ``dyn_accuracy``),
* ``exit_probs`` and the per-pass ``history``,
* the pytree *structure* of params (so load needs no tree_like from the
  caller — pruned/factored trees have data-dependent shapes the caller
  cannot reconstruct),
* the PRNG key data.

``step`` is the number of passes applied (0 = trained baseline), which is
what lets ``Pipeline.run(checkpoint_dir=...)`` resume mid-chain.  The
family adapter is NOT serialized — it holds the data source; the caller
passes it to :func:`load_chain_state`.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os

import jax
import numpy as np

from repro.checkpoint.manager import (latest_step, load_checkpoint,
                                      save_checkpoint)


def _spec(tree):
    """JSON-able structure descriptor of a pytree of dict/list/tuple."""
    if isinstance(tree, dict):
        return {'kind': 'dict', 'items': {k: _spec(v) for k, v in
                                          tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {'kind': type(tree).__name__,
                'items': [_spec(v) for v in tree]}
    return None                                   # leaf


def _skeleton(spec):
    """Rebuild a same-structure tree with placeholder leaves (the
    ``tree_like`` that manager.load_checkpoint keys its arrays by)."""
    if spec is None:
        return np.zeros((), np.float32)
    if spec['kind'] == 'dict':
        return {k: _skeleton(v) for k, v in spec['items'].items()}
    seq = [_skeleton(v) for v in spec['items']]
    return tuple(seq) if spec['kind'] == 'tuple' else seq


def _tuplify(v):
    return tuple(_tuplify(x) for x in v) if isinstance(v, list) else v


def _meta_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f'chain_{step:08d}.json')


def save_chain_state(ckpt_dir: str, state, step: int = 0) -> str:
    """Persist a ChainState as checkpoint ``step`` (atomic; see manager).

    The JSON sidecar is committed BEFORE the npz step dir: ``latest_step``
    only sees committed step dirs, so a crash between the two leaves the
    previous step fully loadable (an orphaned sidecar is harmless and gets
    overwritten by the next save of that step)."""
    tree = {'params': state.params,
            'key': np.asarray(jax.random.key_data(state.key))}
    os.makedirs(ckpt_dir, exist_ok=True)
    cfg = state.cfg
    meta = {
        'step': step,
        'cfg_class': f'{type(cfg).__module__}:{type(cfg).__qualname__}',
        'cfg': dataclasses.asdict(cfg),
        'spec': _spec(tree),
        'scalars': {k: getattr(state, k) for k in
                    ('base_bitops', 'base_bits', 'prune_scale',
                     'lowrank_scale', 'exit_threshold', 'dyn_accuracy')},
        'exit_probs': (None if state.exit_probs is None
                       else {str(k): v for k, v in state.exit_probs.items()}),
        'history': state.history,
    }
    tmp = _meta_path(ckpt_dir, step) + '.tmp'
    with open(tmp, 'w') as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, _meta_path(ckpt_dir, step))
    return save_checkpoint(ckpt_dir, step, tree)


def load_chain_state(ckpt_dir: str, family, step: int | None = None):
    """Restore ``(ChainState, step)`` saved by :func:`save_chain_state`.

    ``step=None`` loads the newest committed step.  ``family`` is the live
    family adapter (data source + hooks) the state should run on.
    """
    from repro.core.passes import ChainState
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f'no chain checkpoints under {ckpt_dir}')
    with open(_meta_path(ckpt_dir, step)) as f:
        meta = json.load(f)
    mod, _, qual = meta['cfg_class'].partition(':')
    cfg_cls = importlib.import_module(mod)
    for part in qual.split('.'):
        cfg_cls = getattr(cfg_cls, part)
    cfg = cfg_cls(**{k: _tuplify(v) for k, v in meta['cfg'].items()})
    tree, _ = load_checkpoint(ckpt_dir, step, _skeleton(meta['spec']))
    exit_probs = meta['exit_probs']
    if exit_probs is not None:
        exit_probs = {int(k): v for k, v in exit_probs.items()}
    state = ChainState(family=family, cfg=cfg, params=tree['params'],
                       key=jax.random.wrap_key_data(tree['key']),
                       exit_probs=exit_probs, history=meta['history'],
                       **meta['scalars'])
    return state, step
