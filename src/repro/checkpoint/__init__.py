from repro.checkpoint.chain_io import (load_chain_state,
                                       save_chain_state)  # noqa: F401
from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      load_checkpoint, save_checkpoint)  # noqa: F401
