from repro.checkpoint.manager import (CheckpointManager, latest_step,
                                      load_checkpoint, save_checkpoint)  # noqa: F401
