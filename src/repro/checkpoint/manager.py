"""Sharded, atomic, async-capable checkpointing (no orbax in the container).

Layout:  <dir>/step_<N>/
            manifest.json      — step, leaf paths, shapes, dtypes
            proc_<i>.npz       — this process's leaf arrays

Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a preempted
save can never corrupt the latest checkpoint (the restart path always reads
the newest *committed* step).  ``CheckpointManager`` adds async saves
(a worker thread snapshots host RAM copies first, so the training loop never
blocks on disk) and retention.

Elastic restore: leaves are saved as full (host-local) arrays; ``restore``
re-device_puts onto whatever shardings the *new* mesh prescribes, so a job
restarted on a smaller/larger pod slice resumes seamlessly (reshard-on-load).
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import zipfile

import jax
import ml_dtypes
import numpy as np

log = logging.getLogger('repro.checkpoint')

SEP = '/'

# npz cannot store ml_dtypes (bfloat16, fp8, ...): stored as raw-bit views
# with the true dtype recorded in the manifest and re-viewed on load.
_BITCAST = {np.dtype(ml_dtypes.bfloat16): np.uint16,
            np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
            np.dtype(ml_dtypes.float8_e5m2): np.uint8}


def _encode(a: np.ndarray):
    if a.dtype in _BITCAST:
        return a.view(_BITCAST[a.dtype]), str(a.dtype)
    return a, str(a.dtype)


def _decode(a: np.ndarray, dtype_str: str):
    for dt, raw in _BITCAST.items():
        if dtype_str == str(dt):
            return a.view(dt)
    return a


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = SEP.join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, process_index=0):
    flat, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f'step_{step:08d}')
    tmp = final + '.tmp'
    os.makedirs(tmp, exist_ok=True)
    enc, dtypes = {}, {}
    for k, v in flat.items():
        enc[k], dtypes[k] = _encode(v)
    np.savez(os.path.join(tmp, f'proc_{process_index}.npz'), **enc)
    manifest = {'step': step,
                'leaves': {k: {'shape': list(v.shape), 'dtype': dtypes[k]}
                           for k, v in flat.items()}}
    mpath = os.path.join(tmp, 'manifest.json')
    with open(mpath, 'w') as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def committed_steps(ckpt_dir: str) -> list[int]:
    """All committed (renamed, non-.tmp) step numbers, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split('_')[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith('step_') and not d.endswith('.tmp'))


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int | None, tree_like, *,
                    shardings=None, process_index=0):
    """Restore into the structure of ``tree_like``; optional resharding."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f'no checkpoints under {ckpt_dir}')
    d = os.path.join(ckpt_dir, f'step_{step:08d}')
    data = np.load(os.path.join(d, f'proc_{process_index}.npz'))
    with open(os.path.join(d, 'manifest.json')) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(tree_like)
    leaves = [_decode(data[k], manifest['leaves'][k]['dtype'])
              for k in flat_like]
    if shardings is not None:
        sh_flat = jax.tree_util.tree_leaves(shardings)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_flat)]
    tree = jax.tree_util.tree_unflatten(
        treedef, [jax.numpy.asarray(l) if not isinstance(l, jax.Array) else l
                  for l in leaves])
    return tree, step


class CheckpointManager:
    def __init__(self, ckpt_dir: str, *, keep: int = 3, async_save=True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        self.wait()
        # snapshot to host RAM synchronously (cheap), write async
        flat, treedef = _flatten(tree)

        def _write():
            snap = jax.tree_util.tree_unflatten(treedef, list(flat.values()))
            save_checkpoint(self.dir, step, snap)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self):
        steps = sorted(int(d.split('_')[1]) for d in os.listdir(self.dir)
                       if d.startswith('step_') and not d.endswith('.tmp'))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f'step_{s:08d}'),
                          ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        """Restore the newest *readable* committed checkpoint.

        The tmp-rename protocol keeps a torn SAVE from ever becoming the
        latest step, but a committed step can still rot afterwards (disk
        corruption, a truncating copy, bit flips).  Rather than dying on
        the newest step's bad manifest/npz, fall back step by step to the
        most recent one that loads — losing ckpt_every steps of progress
        beats losing the job.  Raises FileNotFoundError only when no
        committed step is readable."""
        self.wait()
        steps = committed_steps(self.dir)
        if not steps:
            raise FileNotFoundError(f'no checkpoints under {self.dir}')
        last_err = None
        for step in reversed(steps):
            try:
                return load_checkpoint(self.dir, step, tree_like,
                                       shardings=shardings)
            except (ValueError, KeyError, OSError, EOFError,
                    zipfile.BadZipFile) as e:   # ValueError covers JSON
                log.warning('checkpoint step %d unreadable (%s); '
                            'falling back', step, e)
                last_err = e
        raise FileNotFoundError(
            f'no readable checkpoint under {self.dir} '
            f'({len(steps)} committed steps, all corrupt)') from last_err
